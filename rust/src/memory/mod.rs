//! Compressed context memory stores — the runtime realisation of
//! Mem(t) = g_update(Mem(t-1), h(t)) (paper Eq. 2).
//!
//! * `ConcatStore` — scalable memory: Mem(t) = [h(1); ...; h(t)]
//!   (CCM-concat). Supports FIFO eviction for the streaming mode.
//! * `MergeStore`  — fixed-size memory: Mem(t) = (1-a_t)Mem(t-1)+a_t h(t)
//!   (CCM-merge, arithmetic or EMA coefficients).
//!
//! Buffers are laid out `[L, M, D]` exactly as the serving artifacts
//! expect, so staging a batch is a contiguous copy per session.

pub mod window;

use anyhow::{bail, Result};

use crate::masks::MergeScheme;

/// Per-layer compressed KV h(t) returned by `compress_chunk`:
/// `k`/`v` are `[L, comp_len, D]` row-major.
#[derive(Debug, Clone)]
pub struct CompressedChunk {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub comp_len: usize,
}

/// A `[L, M, D]` KV buffer pair with a valid prefix.
#[derive(Debug, Clone)]
pub struct MemBuffers {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub len: usize,
    pub layers: usize,
    pub slots: usize,
    pub d_model: usize,
}

impl MemBuffers {
    pub fn new(layers: usize, slots: usize, d_model: usize) -> MemBuffers {
        MemBuffers {
            k: vec![0.0; layers * slots * d_model],
            v: vec![0.0; layers * slots * d_model],
            len: 0,
            layers,
            slots,
            d_model,
        }
    }

    /// Bytes of live attention KV (the paper's context-memory metric).
    pub fn kv_bytes(&self) -> usize {
        2 * self.layers * self.len * self.d_model * 4
    }

    /// Copy `h` (`[L, cl, D]`) into slots `[dst, dst+cl)` of every layer.
    fn write(&mut self, dst: usize, h_k: &[f32], h_v: &[f32], cl: usize) {
        let (m, d) = (self.slots, self.d_model);
        debug_assert_eq!(h_k.len(), self.layers * cl * d);
        for l in 0..self.layers {
            let src = l * cl * d;
            let off = (l * m + dst) * d;
            self.k[off..off + cl * d].copy_from_slice(&h_k[src..src + cl * d]);
            self.v[off..off + cl * d].copy_from_slice(&h_v[src..src + cl * d]);
        }
    }

    /// Blend `h` into slots `[0, cl)`: mem = (1-a)*mem + a*h.
    fn blend(&mut self, h_k: &[f32], h_v: &[f32], cl: usize, a: f32) {
        let (m, d) = (self.slots, self.d_model);
        for l in 0..self.layers {
            let src = l * cl * d;
            let off = l * m * d;
            for i in 0..cl * d {
                self.k[off + i] = (1.0 - a) * self.k[off + i] + a * h_k[src + i];
                self.v[off + i] = (1.0 - a) * self.v[off + i] + a * h_v[src + i];
            }
        }
    }

    /// Drop the oldest `n` slots (shift left) — streaming eviction.
    fn evict_oldest(&mut self, n: usize) {
        let n = n.min(self.len);
        let (m, d) = (self.slots, self.d_model);
        for l in 0..self.layers {
            let off = l * m * d;
            self.k.copy_within(off + n * d..off + self.len * d, off);
            self.v.copy_within(off + n * d..off + self.len * d, off);
        }
        self.len -= n;
    }
}

/// The g_update policy.
#[derive(Debug, Clone)]
pub enum UpdateKind {
    Concat,
    Merge(MergeScheme),
}

/// A session's compressed context memory.
#[derive(Debug, Clone)]
pub struct MemoryStore {
    pub buffers: MemBuffers,
    pub kind: UpdateKind,
    /// Number of updates applied (the t in a_t).
    pub t: usize,
    pub comp_len: usize,
}

impl MemoryStore {
    pub fn concat(layers: usize, slots: usize, d_model: usize, comp_len: usize) -> MemoryStore {
        MemoryStore {
            buffers: MemBuffers::new(layers, slots, d_model),
            kind: UpdateKind::Concat,
            t: 0,
            comp_len,
        }
    }

    pub fn merge(
        layers: usize,
        slots: usize,
        d_model: usize,
        comp_len: usize,
        scheme: MergeScheme,
    ) -> MemoryStore {
        assert!(slots >= comp_len);
        MemoryStore {
            buffers: MemBuffers::new(layers, slots, d_model),
            kind: UpdateKind::Merge(scheme),
            t: 0,
            comp_len,
        }
    }

    /// Apply Mem(t) = g_update(Mem(t-1), h(t)).
    pub fn update(&mut self, h: &CompressedChunk) -> Result<()> {
        if h.comp_len != self.comp_len {
            bail!("comp_len mismatch: {} != {}", h.comp_len, self.comp_len);
        }
        self.t += 1;
        match self.kind {
            UpdateKind::Concat => {
                if self.buffers.len + h.comp_len > self.buffers.slots {
                    bail!(
                        "concat memory overflow: {} + {} > {} (evict first)",
                        self.buffers.len,
                        h.comp_len,
                        self.buffers.slots
                    );
                }
                let dst = self.buffers.len;
                self.buffers.write(dst, &h.k, &h.v, h.comp_len);
                self.buffers.len += h.comp_len;
            }
            UpdateKind::Merge(scheme) => {
                let a = scheme.coeff(self.t);
                self.buffers.blend(&h.k, &h.v, h.comp_len, a);
                self.buffers.len = h.comp_len;
            }
        }
        Ok(())
    }

    /// Free slots available before overflow (concat) — merge never grows.
    pub fn free_slots(&self) -> usize {
        match self.kind {
            UpdateKind::Concat => self.buffers.slots - self.buffers.len,
            UpdateKind::Merge(_) => usize::MAX,
        }
    }

    /// Evict the oldest `n_chunks` compressed chunks (concat only).
    pub fn evict_chunks(&mut self, n_chunks: usize) {
        if matches!(self.kind, UpdateKind::Concat) {
            self.buffers.evict_oldest(n_chunks * self.comp_len);
        }
    }

    pub fn len(&self) -> usize {
        self.buffers.len
    }

    pub fn is_empty(&self) -> bool {
        self.buffers.len == 0
    }

    pub fn kv_bytes(&self) -> usize {
        self.buffers.kv_bytes()
    }

    pub fn reset(&mut self) {
        self.buffers.k.iter_mut().for_each(|x| *x = 0.0);
        self.buffers.v.iter_mut().for_each(|x| *x = 0.0);
        self.buffers.len = 0;
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(layers: usize, cl: usize, d: usize, fill: f32) -> CompressedChunk {
        CompressedChunk {
            k: vec![fill; layers * cl * d],
            v: vec![fill * 2.0; layers * cl * d],
            comp_len: cl,
        }
    }

    #[test]
    fn concat_appends_in_order() {
        let mut m = MemoryStore::concat(2, 6, 3, 2);
        m.update(&chunk(2, 2, 3, 1.0)).unwrap();
        m.update(&chunk(2, 2, 3, 2.0)).unwrap();
        assert_eq!(m.len(), 4);
        // Layer 0 slots: [1,1,  2,2, 0] x d
        assert_eq!(m.buffers.k[0], 1.0);
        assert_eq!(m.buffers.k[2 * 3], 2.0);
        // Layer 1 offset: slot stride is 6*3.
        assert_eq!(m.buffers.k[6 * 3], 1.0);
        m.update(&chunk(2, 2, 3, 3.0)).unwrap();
        assert!(m.update(&chunk(2, 2, 3, 4.0)).is_err(), "overflow detected");
    }

    #[test]
    fn merge_is_cumulative_average() {
        let mut m = MemoryStore::merge(1, 2, 1, 2, MergeScheme::Avg);
        for (t, x) in [10.0f32, 20.0, 30.0].iter().enumerate() {
            m.update(&chunk(1, 2, 1, *x)).unwrap();
            assert_eq!(m.t, t + 1);
        }
        assert!((m.buffers.k[0] - 20.0).abs() < 1e-5); // mean(10,20,30)
        assert_eq!(m.len(), 2);
        assert_eq!(m.kv_bytes(), 2 * 1 * 2 * 1 * 4);
    }

    #[test]
    fn merge_ema_recurrence() {
        let mut m = MemoryStore::merge(1, 1, 1, 1, MergeScheme::Ema(0.5));
        m.update(&chunk(1, 1, 1, 8.0)).unwrap(); // a_1 = 1 -> 8
        m.update(&chunk(1, 1, 1, 0.0)).unwrap(); // 0.5*8 = 4
        m.update(&chunk(1, 1, 1, 2.0)).unwrap(); // 0.5*4+0.5*2 = 3
        assert!((m.buffers.k[0] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn eviction_shifts_left() {
        let mut m = MemoryStore::concat(2, 6, 2, 2);
        m.update(&chunk(2, 2, 2, 1.0)).unwrap();
        m.update(&chunk(2, 2, 2, 2.0)).unwrap();
        m.update(&chunk(2, 2, 2, 3.0)).unwrap();
        m.evict_chunks(1);
        assert_eq!(m.len(), 4);
        assert_eq!(m.buffers.k[0], 2.0);
        assert_eq!(m.buffers.k[2 * 2], 3.0);
        // Layer 1 shifted too.
        assert_eq!(m.buffers.k[6 * 2], 2.0);
    }

    #[test]
    fn kv_bytes_tracks_len() {
        let mut m = MemoryStore::concat(4, 48, 128, 4);
        assert_eq!(m.kv_bytes(), 0);
        m.update(&chunk(4, 4, 128, 0.5)).unwrap();
        assert_eq!(m.kv_bytes(), 2 * 4 * 4 * 128 * 4);
    }

    #[test]
    fn reset_clears() {
        let mut m = MemoryStore::merge(1, 2, 2, 2, MergeScheme::Avg);
        m.update(&chunk(1, 2, 2, 5.0)).unwrap();
        m.reset();
        assert_eq!(m.len(), 0);
        assert_eq!(m.t, 0);
        assert!(m.buffers.k.iter().all(|&x| x == 0.0));
    }
}
