//! Compression engine: the serving-side bridge between the coordinator
//! and the AOT artifacts.
//!
//! Implements the online operations of Figure 5:
//!   h(t)  = g_comp(Mem(t-1), c(t))   -> `compress_*` (compress_chunk)
//!   Ô(t) ~ f(· | Mem(t), I(t))        -> `infer_*`   (infer_with_mem)
//! with batched variants that pack several sessions into one artifact
//! call (the dynamic batcher feeds these).

pub mod strategy;

pub use strategy::{CompressionStrategy, StrategyKind, StrategyState, TierConfig, Tiers};

use anyhow::{bail, Result};

use crate::memory::{CompressedChunk, MemoryStore};
use crate::model::Checkpoint;
use crate::runtime::{Runtime, Value};
use crate::tensor::{IntTensor, Tensor};

/// One compression work item: a session's memory + the new chunk.
pub struct CompressItem<'a> {
    pub mem: &'a MemoryStore,
    pub chunk: &'a [i32],
    /// Absolute position of the chunk's first token.
    pub pos_start: usize,
}

/// One inference work item: a session's memory + the input tokens.
pub struct InferItem<'a> {
    pub mem: &'a MemoryStore,
    pub tokens: &'a [i32],
    pub pos_start: usize,
}

/// Max variant when saturated; otherwise smallest variant >= n.
pub fn pick_batch(variants: &[usize], n: usize) -> usize {
    // lint: allow(unwrap) — an empty variant list is a manifest bug
    // caught at load time; dying loudly beats padding to a zero batch.
    let max = *variants.iter().max().expect("no batch variants");
    if n >= max {
        return max;
    }
    variants.iter().copied().filter(|&b| b >= n).min().unwrap_or(max)
}

pub struct Engine<'rt> {
    pub rt: &'rt Runtime,
    pub ck: &'rt Checkpoint,
    /// Active `<COMP>` length (<= comp_len_max baked into the artifacts).
    pub comp_len: usize,
}

impl<'rt> Engine<'rt> {
    pub fn new(rt: &'rt Runtime, ck: &'rt Checkpoint, comp_len: usize) -> Result<Engine<'rt>> {
        let max = rt.manifest.scenario.comp_len_max;
        if comp_len == 0 || comp_len > max {
            bail!("comp_len {comp_len} outside 1..={max}");
        }
        Ok(Engine { rt, ck, comp_len })
    }

    /// Pick the artifact batch variant for `n` pending items: the max
    /// variant when saturated, else the smallest variant that fits all
    /// of them (padding beats multiple small calls — §Perf L3).
    fn batch_variant(&self, n: usize) -> usize {
        pick_batch(&self.rt.manifest.scenario.infer_batches, n)
    }

    fn params(&self) -> Result<[Value; 2]> {
        let nb = self.rt.manifest.base_layout.total;
        let nl = self.rt.manifest.lora_layout.total;
        Ok([
            Value::vec_f32(&[nb], self.ck.base.data.clone())?,
            Value::vec_f32(&[nl], self.ck.lora.data.clone())?,
        ])
    }

    /// Compress a batch of chunks; returns h(t) per item (in order).
    pub fn compress(&self, items: &[CompressItem]) -> Result<Vec<CompressedChunk>> {
        let m = &self.rt.manifest.model;
        let sc = &self.rt.manifest.scenario;
        let (l, d, mm) = (m.n_layers, m.d_model, sc.mem_slots);
        let (sc_max, cl_max) = (sc.chunk_max, sc.comp_len_max);
        let scc = sc_max + cl_max;
        let mut out = Vec::with_capacity(items.len());
        let mut i = 0;
        while i < items.len() {
            let b = self.batch_variant(items.len() - i);
            let group = &items[i..(i + b).min(items.len())];
            i += group.len();

            let mut mem_k = Tensor::zeros(&[b, l, mm, d]);
            let mut mem_v = Tensor::zeros(&[b, l, mm, d]);
            let mut mem_len = IntTensor::zeros(&[b]);
            let mut tokens = IntTensor::zeros(&[b, scc]);
            let mut comp_slot = IntTensor::zeros(&[b, scc]);
            let mut gate = Tensor::zeros(&[b, scc]);
            let mut pos = IntTensor::zeros(&[b, scc]);
            for (bi, item) in group.iter().enumerate() {
                if item.chunk.len() > sc_max {
                    bail!("chunk len {} > chunk_max {}", item.chunk.len(), sc_max);
                }
                let bufs = &item.mem.buffers;
                let n = l * mm * d;
                mem_k.data[bi * n..(bi + 1) * n].copy_from_slice(&bufs.k);
                mem_v.data[bi * n..(bi + 1) * n].copy_from_slice(&bufs.v);
                mem_len.data[bi] = bufs.len as i32;
                let trow = tokens.row_mut(&[bi]);
                trow[..item.chunk.len()].copy_from_slice(item.chunk);
                for s in 0..self.comp_len {
                    trow[sc_max + s] = m.comp_id;
                }
                let srow = comp_slot.row_mut(&[bi]);
                let grow = gate.row_mut(&[bi]);
                for s in 0..self.comp_len {
                    srow[sc_max + s] = s as i32 + 1;
                    grow[sc_max + s] = 1.0;
                }
                let prow = pos.row_mut(&[bi]);
                for (j, p) in prow[..item.chunk.len()].iter_mut().enumerate() {
                    *p = (item.pos_start + j) as i32;
                }
                for s in 0..cl_max {
                    prow[sc_max + s] =
                        (item.pos_start + item.chunk.len() + s).min(m.max_pos - 1) as i32;
                }
            }
            let [base, lora] = self.params()?;
            let outs = self.rt.execute_f32(
                &format!("compress_chunk_b{b}"),
                &[
                    base,
                    lora,
                    Value::F32(mem_k),
                    Value::F32(mem_v),
                    Value::I32(mem_len),
                    Value::I32(tokens),
                    Value::I32(comp_slot),
                    Value::F32(gate),
                    Value::I32(pos),
                ],
            )?;
            // Outputs: hk, hv of shape [b, L, cl_max, D]; slice comp_len.
            let (hk, hv) = (&outs[0], &outs[1]);
            for (bi, _) in group.iter().enumerate() {
                let mut k = Vec::with_capacity(l * self.comp_len * d);
                let mut v = Vec::with_capacity(l * self.comp_len * d);
                for li in 0..l {
                    for s in 0..self.comp_len {
                        k.extend_from_slice(hk.row(&[bi, li, s]));
                        v.extend_from_slice(hv.row(&[bi, li, s]));
                    }
                }
                out.push(CompressedChunk { k, v, comp_len: self.comp_len });
            }
        }
        Ok(out)
    }

    /// Score a batch of inputs against their sessions' memories.
    /// Returns logits rows `[Si, V]` per item.
    pub fn infer(&self, items: &[InferItem]) -> Result<Vec<Tensor>> {
        let m = &self.rt.manifest.model;
        let sc = &self.rt.manifest.scenario;
        let (l, d, mm, si) = (m.n_layers, m.d_model, sc.mem_slots, sc.input_max);
        let mut out = Vec::with_capacity(items.len());
        let mut i = 0;
        while i < items.len() {
            let b = self.batch_variant(items.len() - i);
            let group = &items[i..(i + b).min(items.len())];
            i += group.len();

            let mut mem_k = Tensor::zeros(&[b, l, mm, d]);
            let mut mem_v = Tensor::zeros(&[b, l, mm, d]);
            let mut mem_len = IntTensor::zeros(&[b]);
            let mut tokens = IntTensor::zeros(&[b, si]);
            let mut pos = IntTensor::zeros(&[b, si]);
            for (bi, item) in group.iter().enumerate() {
                if item.tokens.len() > si {
                    bail!("input len {} > input_max {}", item.tokens.len(), si);
                }
                let bufs = &item.mem.buffers;
                let n = l * mm * d;
                mem_k.data[bi * n..(bi + 1) * n].copy_from_slice(&bufs.k);
                mem_v.data[bi * n..(bi + 1) * n].copy_from_slice(&bufs.v);
                mem_len.data[bi] = bufs.len as i32;
                tokens.row_mut(&[bi])[..item.tokens.len()].copy_from_slice(item.tokens);
                let prow = pos.row_mut(&[bi]);
                for (j, p) in prow[..item.tokens.len()].iter_mut().enumerate() {
                    *p = ((item.pos_start + j).min(m.max_pos - 1)) as i32;
                }
            }
            let [base, lora] = self.params()?;
            let outs = self.rt.execute_f32(
                &format!("infer_with_mem_b{b}"),
                &[
                    base,
                    lora,
                    Value::F32(mem_k),
                    Value::F32(mem_v),
                    Value::I32(mem_len),
                    Value::I32(tokens),
                    Value::I32(pos),
                ],
            )?;
            let logits = &outs[0]; // [b, Si, V]
            for bi in 0..group.len() {
                let mut rows = Tensor::zeros(&[si, m.vocab]);
                for s in 0..si {
                    rows.row_mut(&[s]).copy_from_slice(logits.row(&[bi, s]));
                }
                out.push(rows);
            }
        }
        Ok(out)
    }
}

/// Backend abstraction over the two serving ops. [`Engine`] is the XLA
/// implementation; [`SimCompute`] is a deterministic host-side
/// implementation used by protocol-level server tests and host-only
/// benches, where AOT artifacts are unavailable or irrelevant.
pub trait Compute {
    /// Active `<COMP>` length per compressed chunk.
    fn comp_len(&self) -> usize;
    /// h(t) = g_comp(Mem(t-1), c(t)) for a batch of items.
    fn compress(&self, items: &[CompressItem]) -> Result<Vec<CompressedChunk>>;
    /// Logits rows `[Si, V]` for a batch of memory-conditioned inputs.
    fn infer(&self, items: &[InferItem]) -> Result<Vec<Tensor>>;
}

impl Compute for Engine<'_> {
    fn comp_len(&self) -> usize {
        self.comp_len
    }

    fn compress(&self, items: &[CompressItem]) -> Result<Vec<CompressedChunk>> {
        Engine::compress(self, items)
    }

    fn infer(&self, items: &[InferItem]) -> Result<Vec<Tensor>> {
        Engine::infer(self, items)
    }
}

/// An [`Engine`] that owns its [`Runtime`] and [`Checkpoint`]: the
/// per-shard backend of multi-executor serving. Each shard's executor
/// thread builds one of these inside a
/// [`crate::server::BackendFactory`] — PJRT runtimes are thread-bound,
/// so the runtime must be created on, and never leave, the thread that
/// drives it.
pub struct OwnedEngine {
    rt: Runtime,
    ck: Checkpoint,
    comp_len: usize,
}

impl OwnedEngine {
    pub fn new(rt: Runtime, ck: Checkpoint, comp_len: usize) -> Result<OwnedEngine> {
        Engine::new(&rt, &ck, comp_len)?; // validate comp_len bounds
        Ok(OwnedEngine { rt, ck, comp_len })
    }

    /// The borrowed view this call delegates through (construction is
    /// two references and a usize — free).
    fn engine(&self) -> Engine<'_> {
        Engine { rt: &self.rt, ck: &self.ck, comp_len: self.comp_len }
    }
}

impl Compute for OwnedEngine {
    fn comp_len(&self) -> usize {
        self.comp_len
    }

    fn compress(&self, items: &[CompressItem]) -> Result<Vec<CompressedChunk>> {
        self.engine().compress(items)
    }

    fn infer(&self, items: &[InferItem]) -> Result<Vec<Tensor>> {
        self.engine().infer(items)
    }
}

/// Deterministic host-side backend: no XLA, no artifacts.
///
/// Compression summarises a chunk into slots filled with the chunk's
/// scaled token mean; inference echoes each input token as the top-1
/// next-token (logit 8.0 at `token % vocab`) plus a small
/// memory-occupancy signal at slot `mem.len() % vocab`. This makes
/// per-session ordering, memory growth, and eviction all observable
/// through the serving protocol, which is what the server integration
/// tests and the serve-throughput bench need. Optional per-batch delays
/// model artifact execution time so scheduling behavior (batching,
/// pipelining, head-of-line effects) can be exercised realistically.
#[derive(Debug, Clone)]
pub struct SimCompute {
    pub layers: usize,
    pub d_model: usize,
    pub vocab: usize,
    pub input_max: usize,
    pub comp_len: usize,
    /// Simulated wall-clock cost per compress batch.
    pub compress_delay: std::time::Duration,
    /// Simulated wall-clock cost per infer batch.
    pub infer_delay: std::time::Duration,
}

impl SimCompute {
    pub fn new(
        layers: usize,
        d_model: usize,
        vocab: usize,
        input_max: usize,
        comp_len: usize,
    ) -> SimCompute {
        SimCompute {
            layers,
            d_model,
            vocab,
            input_max,
            comp_len,
            compress_delay: std::time::Duration::ZERO,
            infer_delay: std::time::Duration::ZERO,
        }
    }

    pub fn from_manifest(m: &crate::model::Manifest) -> SimCompute {
        SimCompute::new(
            m.model.n_layers,
            m.model.d_model,
            m.model.vocab,
            m.scenario.input_max,
            m.scenario.comp_len_max,
        )
    }
}

impl Compute for SimCompute {
    fn comp_len(&self) -> usize {
        self.comp_len
    }

    fn compress(&self, items: &[CompressItem]) -> Result<Vec<CompressedChunk>> {
        if !self.compress_delay.is_zero() {
            std::thread::sleep(self.compress_delay);
        }
        items
            .iter()
            .map(|item| {
                let sum: f32 = item.chunk.iter().map(|&t| t as f32).sum();
                let fill = sum / item.chunk.len().max(1) as f32 / 1e3;
                let n = self.layers * self.comp_len * self.d_model;
                Ok(CompressedChunk { k: vec![fill; n], v: vec![fill; n], comp_len: self.comp_len })
            })
            .collect()
    }

    fn infer(&self, items: &[InferItem]) -> Result<Vec<Tensor>> {
        if !self.infer_delay.is_zero() {
            std::thread::sleep(self.infer_delay);
        }
        items
            .iter()
            .map(|item| {
                if item.tokens.len() > self.input_max {
                    bail!("input len {} > input_max {}", item.tokens.len(), self.input_max);
                }
                let mut rows = Tensor::zeros(&[self.input_max, self.vocab]);
                for (i, &tok) in item.tokens.iter().enumerate() {
                    let row = rows.row_mut(&[i]);
                    row[tok.unsigned_abs() as usize % self.vocab] = 8.0;
                    row[item.mem.len() % self.vocab] += 0.5;
                }
                Ok(rows)
            })
            .collect()
    }
}

/// Next-token average log-likelihood of `target` given logits over the
/// packed `[input ++ target]` rows (targets start at `input_len`).
pub fn target_avg_loglik(logits: &Tensor, input_len: usize, target: &[i32]) -> f64 {
    let v = logits.shape[1];
    let mut total = 0.0f64;
    for (i, &tgt) in target.iter().enumerate() {
        // logits row predicting this target is the *previous* position.
        let row = logits.row(&[input_len + i - 1]);
        debug_assert_eq!(row.len(), v);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f32 = row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln() + mx;
        total += (row[tgt as usize] - lse) as f64;
    }
    total / target.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn avg_loglik_of_uniform_logits() {
        let v = 8;
        let logits = Tensor::zeros(&[4, v]);
        let ll = target_avg_loglik(&logits, 2, &[3, 5]);
        assert!((ll - (1.0 / v as f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn sim_compute_echoes_tokens_and_sees_memory() {
        let sim = SimCompute::new(2, 4, 16, 8, 2);
        let mut mem = MemoryStore::concat(2, 8, 4, 2);
        let items = [CompressItem { mem: &mem, chunk: &[4, 6], pos_start: 0 }];
        let h = sim.compress(&items).unwrap();
        assert_eq!(h[0].k.len(), 2 * 2 * 4);
        mem.update(&h[0]).unwrap();
        assert_eq!(mem.len(), 2);
        let items = [InferItem { mem: &mem, tokens: &[5, 9], pos_start: 0 }];
        let rows = sim.infer(&items).unwrap();
        // Top-1 at the last input position is the echoed token.
        let row = rows[0].row(&[1]);
        let top = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        assert_eq!(top, 9);
        // Memory-occupancy signal sits at mem.len() % vocab.
        assert!(row[2] > 0.0);
    }

    #[test]
    fn avg_loglik_prefers_peaked_logits() {
        let mut logits = Tensor::zeros(&[3, 4]);
        logits.set(&[1, 2], 10.0); // position 1 predicts target[0]
        let peaked = target_avg_loglik(&logits, 2, &[2]);
        let other = target_avg_loglik(&logits, 2, &[1]);
        assert!(peaked > other);
        assert!(peaked > -0.01);
    }
}
