//! Pluggable per-session compression strategies — the serving-time
//! counterpart of the paper's method/baseline axis (CCM vs sliding
//! window vs full context), selected per session at admission.
//!
//! [`StrategyKind`] is the config/wire surface (mirroring how
//! `EvictionKind` parses/builds eviction policies); the
//! [`CompressionStrategy`] trait is the behavior seam the coordinator
//! dispatches through: whether a context chunk runs the backend g_comp
//! op or is absorbed session-locally, what token stream an inference
//! conditions on, and how the session's live KV is costed — so the KV
//! budget sees cheap tiers as cheap and the full-context reference tier
//! as expensive.
//!
//! Tier shape (QoS token-bucket refill/burst and the sliding-window
//! retention budget) is carried by [`TierConfig`] / [`Tiers`], parsed
//! from the `--tiers` flag.

use anyhow::{bail, Result};

use crate::memory::window::{Overflow, StreamWindow};
use crate::memory::MemoryStore;

/// Config-surface selector for the built-in compression strategies
/// (the `--strategy` CLI flag, the `op:"context"` request field, and
/// the shard-IPC wire byte). Custom behavior still enters through
/// [`CompressionStrategy`] impls.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum StrategyKind {
    /// Compressed context memory: chunks run g_comp, Mem(t) holds the
    /// result (the paper's method — the default serving tier).
    #[default]
    Ccm,
    /// StreamingLLM-style retention: sink + recent raw tokens under a
    /// fixed KV budget, no compression calls (promoted from the
    /// eval-only `memory::window` module).
    SlidingWindow,
    /// Full-context reference tier: every raw context token is
    /// retained, KV grows linearly (the paper's upper baseline).
    NoCompress,
}

impl StrategyKind {
    /// Every kind, in [`StrategyKind::index`] order (counter arrays and
    /// stats rendering iterate this).
    pub const ALL: [StrategyKind; 3] =
        [StrategyKind::Ccm, StrategyKind::SlidingWindow, StrategyKind::NoCompress];

    pub fn parse(name: &str) -> Result<StrategyKind> {
        Ok(match name {
            "ccm" => StrategyKind::Ccm,
            "sliding-window" | "window" => StrategyKind::SlidingWindow,
            "none" | "no-compress" | "full" => StrategyKind::NoCompress,
            other => bail!("unknown compression strategy {other:?} (ccm|sliding-window|none)"),
        })
    }

    /// Stable label used in stats JSON, CLI output, and docs.
    pub fn name(self) -> &'static str {
        match self {
            StrategyKind::Ccm => "ccm",
            StrategyKind::SlidingWindow => "sliding-window",
            StrategyKind::NoCompress => "none",
        }
    }

    /// Dense index into per-strategy counter arrays.
    pub fn index(self) -> usize {
        match self {
            StrategyKind::Ccm => 0,
            StrategyKind::SlidingWindow => 1,
            StrategyKind::NoCompress => 2,
        }
    }

    /// Nonzero wire byte for the binary shard-IPC codec (0 is reserved
    /// for "absent" in optional positions).
    pub fn wire(self) -> u8 {
        self.index() as u8 + 1
    }

    pub fn from_wire(b: u8) -> Result<StrategyKind> {
        match b {
            1 => Ok(StrategyKind::Ccm),
            2 => Ok(StrategyKind::SlidingWindow),
            3 => Ok(StrategyKind::NoCompress),
            other => bail!("unknown strategy wire byte {other}"),
        }
    }

    /// Build the strategy behavior for this kind under a tier config.
    /// `mem_slots` is the manifest's compressed-memory capacity: the
    /// sliding-window tier defaults its retention budget to it, so the
    /// two tiers compare at equal KV (the paper's budget-fair setup).
    pub fn build(self, tier: &TierConfig, mem_slots: usize) -> Box<dyn CompressionStrategy> {
        match self {
            StrategyKind::Ccm => Box::new(Ccm),
            StrategyKind::SlidingWindow => {
                let window_kv = if tier.window_kv > 0 { tier.window_kv } else { mem_slots.max(2) };
                let n_sink = tier.n_sink.min(window_kv / 2);
                Box::new(SlidingWindow { window_kv, n_sink })
            }
            StrategyKind::NoCompress => Box::new(NoCompress),
        }
    }
}

/// Per-tier serving shape: the QoS token bucket governing priority
/// overrides in the batcher, plus the sliding-window retention budget
/// (ignored by the other strategies).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierConfig {
    /// Priority-override tokens a session regains per second.
    pub refill_per_sec: f64,
    /// Bucket depth: max consecutive overrides one session can spend
    /// (bounds how far a query flood can delay another tenant).
    pub burst: f64,
    /// Sliding-window retained-token budget; 0 derives it from the
    /// manifest's `mem_slots` (equal-KV comparison with the CCM tier).
    pub window_kv: usize,
    /// Attention-sink tokens pinned at the stream head.
    pub n_sink: usize,
}

impl Default for TierConfig {
    fn default() -> TierConfig {
        TierConfig { refill_per_sec: 8.0, burst: 4.0, window_kv: 0, n_sink: 4 }
    }
}

/// Per-strategy tier table (the `--tiers` flag). Unlisted tiers keep
/// [`TierConfig::default`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Tiers {
    per: [TierConfig; 3],
}

impl Tiers {
    pub fn get(&self, k: StrategyKind) -> &TierConfig {
        &self.per[k.index()]
    }

    pub fn get_mut(&mut self, k: StrategyKind) -> &mut TierConfig {
        &mut self.per[k.index()]
    }

    /// Parse a `--tiers` spec: comma-separated `kind=refill/burst` or
    /// `kind=refill/burst/window_kv` entries, e.g.
    /// `ccm=16/8,none=2/1` or `sliding-window=8/4/64`.
    pub fn parse(spec: &str) -> Result<Tiers> {
        let mut tiers = Tiers::default();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let Some((kind, shape)) = entry.split_once('=') else {
                bail!("tier entry {entry:?} is not kind=refill/burst[/window_kv]");
            };
            let kind = StrategyKind::parse(kind.trim())?;
            let parts: Vec<&str> = shape.split('/').collect();
            if parts.len() < 2 || parts.len() > 3 {
                bail!("tier shape {shape:?} is not refill/burst[/window_kv]");
            }
            let refill: f64 = match parts[0].trim().parse() {
                Ok(v) if v >= 0.0 => v,
                _ => bail!("tier refill {:?} is not a non-negative number", parts[0]),
            };
            let burst: f64 = match parts[1].trim().parse() {
                Ok(v) if v >= 0.0 => v,
                _ => bail!("tier burst {:?} is not a non-negative number", parts[1]),
            };
            let cfg = tiers.get_mut(kind);
            cfg.refill_per_sec = refill;
            cfg.burst = burst;
            if parts.len() == 3 {
                cfg.window_kv = match parts[2].trim().parse() {
                    Ok(v) => v,
                    _ => bail!("tier window_kv {:?} is not an integer", parts[2]),
                };
            }
        }
        Ok(tiers)
    }
}

/// Per-session state a strategy maintains beside the compressed
/// [`MemoryStore`]: the raw tokens it retains verbatim.
#[derive(Debug, Clone)]
pub enum StrategyState {
    /// CCM retains nothing raw — context lives in Mem(t).
    Ccm,
    /// Sliding-window retention (sink + recent tokens, hard budget).
    Window(StreamWindow),
    /// Full raw context (the no-compress reference tier).
    Full(Vec<i32>),
}

impl StrategyState {
    /// Raw tokens currently retained (token-equivalents of live KV on
    /// top of the compressed memory).
    pub fn raw_kv_tokens(&self) -> usize {
        match self {
            StrategyState::Ccm => 0,
            StrategyState::Window(w) => w.kv_size(),
            StrategyState::Full(tail) => tail.len(),
        }
    }
}

/// The strategy seam: how context chunks become session state, what an
/// inference conditions on, and what the session's live KV costs.
/// One impl per [`StrategyKind`]; the coordinator keeps a built
/// instance per kind and batches stay homogeneous in (kind, strategy).
pub trait CompressionStrategy: Send + Sync {
    fn kind(&self) -> StrategyKind;

    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Fresh per-session retention state.
    fn new_state(&self) -> StrategyState;

    /// True when context chunks run the backend compress op (batched
    /// g_comp, the CCM path); false when absorption is session-local.
    fn compresses(&self) -> bool;

    /// Session-local absorption of one context chunk (non-compressing
    /// tiers). Returns how many retained tokens were dropped to stay
    /// inside the tier's budget.
    fn absorb(&self, state: &mut StrategyState, chunk: &[i32]) -> usize;

    /// The token stream an inference conditions on: retained context
    /// followed by the query, clamped to the newest `input_max` tokens.
    fn stage_input(&self, state: &StrategyState, query: &[i32], input_max: usize) -> Vec<i32>;

    /// Live KV bytes for a session under this strategy: compressed
    /// memory plus retained raw tokens at full per-token KV cost.
    fn kv_bytes(&self, state: &StrategyState, mem: &MemoryStore) -> usize {
        let per_tok = 2 * mem.buffers.layers * mem.buffers.d_model * 4;
        mem.kv_bytes() + state.raw_kv_tokens() * per_tok
    }
}

/// The paper's method: context chunks are compressed by the backend
/// into Mem(t); inference conditions on Mem(t) ++ query.
pub struct Ccm;

impl CompressionStrategy for Ccm {
    fn kind(&self) -> StrategyKind {
        StrategyKind::Ccm
    }

    fn new_state(&self) -> StrategyState {
        StrategyState::Ccm
    }

    fn compresses(&self) -> bool {
        true
    }

    fn absorb(&self, _state: &mut StrategyState, _chunk: &[i32]) -> usize {
        debug_assert!(false, "ccm chunks go through the backend compress path");
        0
    }

    fn stage_input(&self, _state: &StrategyState, query: &[i32], input_max: usize) -> Vec<i32> {
        query[query.len().saturating_sub(input_max)..].to_vec()
    }
}

/// StreamingLLM-style serving tier: `[sink | recent window]` raw tokens
/// under a hard budget; overflow is dropped, never compressed.
pub struct SlidingWindow {
    pub window_kv: usize,
    pub n_sink: usize,
}

impl CompressionStrategy for SlidingWindow {
    fn kind(&self) -> StrategyKind {
        StrategyKind::SlidingWindow
    }

    fn new_state(&self) -> StrategyState {
        StrategyState::Window(StreamWindow::streaming_llm(self.window_kv, self.n_sink))
    }

    fn compresses(&self) -> bool {
        false
    }

    fn absorb(&self, state: &mut StrategyState, chunk: &[i32]) -> usize {
        let StrategyState::Window(w) = state else {
            debug_assert!(false, "sliding-window session without window state");
            return 0;
        };
        let mut dropped = 0;
        for &tok in chunk {
            match w.push(tok) {
                Overflow::Drop(n) => dropped += n,
                Overflow::None => {}
                // streaming_llm windows never emit Compress.
                Overflow::Compress(_) => debug_assert!(false, "drop-mode window compressed"),
            }
        }
        dropped
    }

    fn stage_input(&self, state: &StrategyState, query: &[i32], input_max: usize) -> Vec<i32> {
        let StrategyState::Window(w) = state else {
            return query[query.len().saturating_sub(input_max)..].to_vec();
        };
        let mut out = Vec::with_capacity(w.kv_size() + query.len());
        out.extend_from_slice(&w.sink);
        out.extend_from_slice(&w.window);
        out.extend_from_slice(query);
        out.drain(..out.len().saturating_sub(input_max));
        out
    }
}

/// Full-context reference tier: everything is retained, nothing is
/// compressed — the expensive end of the fidelity/memory trade-off.
pub struct NoCompress;

impl CompressionStrategy for NoCompress {
    fn kind(&self) -> StrategyKind {
        StrategyKind::NoCompress
    }

    fn new_state(&self) -> StrategyState {
        StrategyState::Full(Vec::new())
    }

    fn compresses(&self) -> bool {
        false
    }

    fn absorb(&self, state: &mut StrategyState, chunk: &[i32]) -> usize {
        let StrategyState::Full(tail) = state else {
            debug_assert!(false, "no-compress session without full state");
            return 0;
        };
        tail.extend_from_slice(chunk);
        0
    }

    fn stage_input(&self, state: &StrategyState, query: &[i32], input_max: usize) -> Vec<i32> {
        let StrategyState::Full(tail) = state else {
            return query[query.len().saturating_sub(input_max)..].to_vec();
        };
        let mut out = Vec::with_capacity(tail.len() + query.len());
        out.extend_from_slice(tail);
        out.extend_from_slice(query);
        out.drain(..out.len().saturating_sub(input_max));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_kind_parses_names_and_wire_bytes() {
        for (s, k) in [
            ("ccm", StrategyKind::Ccm),
            ("sliding-window", StrategyKind::SlidingWindow),
            ("window", StrategyKind::SlidingWindow),
            ("none", StrategyKind::NoCompress),
            ("no-compress", StrategyKind::NoCompress),
            ("full", StrategyKind::NoCompress),
        ] {
            assert_eq!(StrategyKind::parse(s).unwrap(), k);
        }
        assert!(StrategyKind::parse("zip").is_err());
        assert_eq!(StrategyKind::default(), StrategyKind::Ccm);
        for k in StrategyKind::ALL {
            assert_eq!(StrategyKind::parse(k.name()).unwrap(), k);
            assert_eq!(StrategyKind::from_wire(k.wire()).unwrap(), k);
            assert_eq!(StrategyKind::ALL[k.index()], k);
        }
        assert!(StrategyKind::from_wire(0).is_err());
        assert!(StrategyKind::from_wire(9).is_err());
    }

    #[test]
    fn tiers_parse_overrides_listed_kinds_only() {
        let t = Tiers::parse("ccm=16/8,sliding-window=2/1/64").unwrap();
        assert_eq!(t.get(StrategyKind::Ccm).refill_per_sec, 16.0);
        assert_eq!(t.get(StrategyKind::Ccm).burst, 8.0);
        assert_eq!(t.get(StrategyKind::SlidingWindow).burst, 1.0);
        assert_eq!(t.get(StrategyKind::SlidingWindow).window_kv, 64);
        // Unlisted tier keeps defaults.
        assert_eq!(t.get(StrategyKind::NoCompress), &TierConfig::default());
        assert!(Tiers::parse("bogus=1/1").is_err());
        assert!(Tiers::parse("ccm=1").is_err());
        assert!(Tiers::parse("ccm=a/b").is_err());
        assert!(Tiers::parse("ccm=1/2/3/4").is_err());
        assert_eq!(Tiers::parse("").unwrap(), Tiers::default());
    }

    #[test]
    fn sliding_window_retains_under_budget_and_reports_drops() {
        let cfg = TierConfig { window_kv: 8, n_sink: 2, ..TierConfig::default() };
        let strat = StrategyKind::SlidingWindow.build(&cfg, 32);
        assert!(!strat.compresses());
        let mut state = strat.new_state();
        // 6 tokens fit (2 sink + 4 window), the rest displace oldest.
        assert_eq!(strat.absorb(&mut state, &(0..6).collect::<Vec<i32>>()), 0);
        assert_eq!(state.raw_kv_tokens(), 6);
        let dropped = strat.absorb(&mut state, &(6..16).collect::<Vec<i32>>());
        assert_eq!(dropped, 8, "budget 8 forces 8 of 16 tokens out");
        assert_eq!(state.raw_kv_tokens(), 8);
        // Staging: sink ++ recent window ++ query, newest-clamped.
        let staged = strat.stage_input(&state, &[99], 64);
        assert_eq!(staged.len(), 9);
        assert_eq!(staged[..2], [0, 1], "sink pinned");
        assert_eq!(*staged.last().unwrap(), 99);
        let clamped = strat.stage_input(&state, &[99], 3);
        assert_eq!(clamped, vec![14, 15, 99], "clamp keeps the newest tokens");
    }

    #[test]
    fn sliding_window_defaults_budget_to_mem_slots() {
        let strat = StrategyKind::SlidingWindow.build(&TierConfig::default(), 16);
        let mut state = strat.new_state();
        strat.absorb(&mut state, &(0..40).collect::<Vec<i32>>());
        assert_eq!(state.raw_kv_tokens(), 16, "equal-KV budget with the CCM tier");
    }

    #[test]
    fn no_compress_retains_everything_and_costs_linearly() {
        let strat = StrategyKind::NoCompress.build(&TierConfig::default(), 8);
        let mut state = strat.new_state();
        assert_eq!(strat.absorb(&mut state, &[1, 2, 3]), 0);
        assert_eq!(strat.absorb(&mut state, &[4, 5]), 0);
        assert_eq!(state.raw_kv_tokens(), 5);
        let mem = MemoryStore::concat(2, 8, 4, 2);
        // 5 raw tokens at 2*L*D*4 bytes each; the (empty) memory adds 0.
        assert_eq!(strat.kv_bytes(&state, &mem), 5 * 2 * 2 * 4 * 4);
        assert_eq!(strat.stage_input(&state, &[9], 4), vec![3, 4, 5, 9]);
    }

    #[test]
    fn ccm_strategy_stages_query_only_and_costs_memory_only() {
        let strat = StrategyKind::Ccm.build(&TierConfig::default(), 8);
        assert!(strat.compresses());
        let state = strat.new_state();
        assert_eq!(state.raw_kv_tokens(), 0);
        assert_eq!(strat.stage_input(&state, &[7, 8], 16), vec![7, 8]);
        let mut mem = MemoryStore::concat(2, 8, 4, 2);
        let n = 2 * 2 * 4;
        mem.update(&crate::memory::CompressedChunk { k: vec![0.0; n], v: vec![0.0; n], comp_len: 2 })
            .unwrap();
        assert_eq!(strat.kv_bytes(&state, &mem), mem.kv_bytes());
    }
}
