//! Manifest: the single source of truth emitted by `python/compile/aot.py`.
//!
//! Carries the model + scenario configuration, the flat parameter
//! layouts, per-artifact I/O signatures, and golden mask vectors used to
//! cross-check `rust/src/masks` against `python/compile/masks.py`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_pos: usize,
    pub lora_rank: usize,
    pub lora_alpha: f32,
    pub pad_id: i32,
    pub bos_id: i32,
    pub sep_id: i32,
    pub comp_id: i32,
    pub d_head: usize,
}

#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub t_max: usize,
    pub chunk_max: usize,
    pub comp_len_max: usize,
    pub input_max: usize,
    pub seq_train: usize,
    pub mem_slots: usize,
    pub batch_train: usize,
    pub infer_batches: Vec<usize>,
    pub decode_cache: usize,
    pub rmt_unroll: usize,
    pub rmt_mem: usize,
}

#[derive(Debug, Clone)]
pub struct LayoutEntry {
    pub name: String,
    pub offset: usize,
    pub size: usize,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ParamLayout {
    pub total: usize,
    pub entries: Vec<LayoutEntry>,
}

impl ParamLayout {
    pub fn entry(&self, name: &str) -> Result<&LayoutEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("no param entry {name:?}"))
    }

    /// Slice a named parameter out of a flat vector.
    pub fn slice<'a>(&self, vec: &'a [f32], name: &str) -> Result<&'a [f32]> {
        let e = self.entry(name)?;
        Ok(&vec[e.offset..e.offset + e.size])
    }
}

#[derive(Debug, Clone)]
pub struct TensorSig {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ArtifactSig {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// One golden mask case from python/compile/masks.py.
#[derive(Debug, Clone)]
pub struct MaskGolden {
    pub method: String,
    pub scheme: String,
    pub chunk_lens: Vec<usize>,
    pub comp_len: usize,
    pub pool: usize,
    pub input_len: usize,
    pub seq: usize,
    pub mem_slots: usize,
    pub kind: Vec<i32>,
    pub step: Vec<i32>,
    pub comp_slot: Vec<i32>,
    pub mask_rows: Vec<String>,
    /// (row, col, weight) nonzeros of the merge matrix P.
    pub p_nonzero: Vec<(usize, usize, f32)>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub config_name: String,
    pub dir: PathBuf,
    pub model: ModelConfig,
    pub scenario: ScenarioConfig,
    pub base_layout: ParamLayout,
    pub lora_layout: ParamLayout,
    pub artifacts: Vec<ArtifactSig>,
    pub mask_goldens: Vec<MaskGolden>,
}

impl Manifest {
    /// Tiny fixed-shape manifest for tests and host-side benches that
    /// exercise coordinator/server paths without AOT artifacts (the
    /// single source of truth for toy dimensions — unit tests, the
    /// server integration tests, and benches all share it).
    pub fn toy() -> Manifest {
        Manifest {
            config_name: "toy".into(),
            dir: PathBuf::from("."),
            model: ModelConfig {
                name: "toy".into(),
                vocab: 32,
                d_model: 4,
                n_layers: 2,
                n_heads: 2,
                d_ff: 8,
                max_pos: 4096,
                lora_rank: 2,
                lora_alpha: 4.0,
                pad_id: 0,
                bos_id: 1,
                sep_id: 2,
                comp_id: 3,
                d_head: 2,
            },
            scenario: ScenarioConfig {
                t_max: 8,
                chunk_max: 8,
                comp_len_max: 2,
                input_max: 8,
                seq_train: 64,
                mem_slots: 8,
                batch_train: 2,
                infer_batches: vec![1, 4],
                decode_cache: 16,
                rmt_unroll: 2,
                rmt_mem: 2,
            },
            base_layout: ParamLayout { total: 4, entries: vec![] },
            lora_layout: ParamLayout { total: 4, entries: vec![] },
            artifacts: vec![],
            mask_goldens: vec![],
        }
    }
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let j = Json::parse(&src).with_context(|| format!("parsing {path:?}"))?;
        Self::from_json(&j, dir)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSig> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    fn from_json(j: &Json, dir: &Path) -> Result<Manifest> {
        let cfg = j.get("config")?;
        let m = cfg.get("model")?;
        let model = ModelConfig {
            name: m.get("name")?.str()?.to_string(),
            vocab: m.get("vocab")?.usize()?,
            d_model: m.get("d_model")?.usize()?,
            n_layers: m.get("n_layers")?.usize()?,
            n_heads: m.get("n_heads")?.usize()?,
            d_ff: m.get("d_ff")?.usize()?,
            max_pos: m.get("max_pos")?.usize()?,
            lora_rank: m.get("lora_rank")?.usize()?,
            lora_alpha: m.get("lora_alpha")?.f64()? as f32,
            pad_id: m.get("pad_id")?.i64()? as i32,
            bos_id: m.get("bos_id")?.i64()? as i32,
            sep_id: m.get("sep_id")?.i64()? as i32,
            comp_id: m.get("comp_id")?.i64()? as i32,
            d_head: m.get("d_head")?.usize()?,
        };
        let s = cfg.get("scenario")?;
        let scenario = ScenarioConfig {
            t_max: s.get("t_max")?.usize()?,
            chunk_max: s.get("chunk_max")?.usize()?,
            comp_len_max: s.get("comp_len_max")?.usize()?,
            input_max: s.get("input_max")?.usize()?,
            seq_train: s.get("seq_train")?.usize()?,
            mem_slots: s.get("mem_slots")?.usize()?,
            batch_train: s.get("batch_train")?.usize()?,
            infer_batches: s.get("infer_batches")?.usize_vec()?,
            decode_cache: s.get("decode_cache")?.usize()?,
            rmt_unroll: s.get("rmt_unroll")?.usize()?,
            rmt_mem: s.get("rmt_mem")?.usize()?,
        };

        let parse_layout = |v: &Json| -> Result<ParamLayout> {
            let mut entries = Vec::new();
            for e in v.get("entries")?.arr()? {
                entries.push(LayoutEntry {
                    name: e.get("name")?.str()?.to_string(),
                    offset: e.get("offset")?.usize()?,
                    size: e.get("size")?.usize()?,
                    shape: e.get("shape")?.usize_vec()?,
                });
            }
            Ok(ParamLayout { total: v.get("total")?.usize()?, entries })
        };
        let params = j.get("params")?;
        let base_layout = parse_layout(params.get("base")?)?;
        let lora_layout = parse_layout(params.get("lora")?)?;

        let parse_sig = |v: &Json| -> Result<TensorSig> {
            Ok(TensorSig {
                name: v.opt("name").map(|n| n.str().unwrap_or("").to_string()).unwrap_or_default(),
                dtype: v.get("dtype")?.str()?.to_string(),
                shape: v.get("shape")?.usize_vec()?,
            })
        };
        let mut artifacts = Vec::new();
        for a in j.get("artifacts")?.arr()? {
            artifacts.push(ArtifactSig {
                name: a.get("name")?.str()?.to_string(),
                file: a.get("file")?.str()?.to_string(),
                inputs: a.get("inputs")?.arr()?.iter().map(&parse_sig).collect::<Result<_>>()?,
                outputs: a.get("outputs")?.arr()?.iter().map(&parse_sig).collect::<Result<_>>()?,
            });
        }

        let mut mask_goldens = Vec::new();
        for g in j.get("mask_goldens")?.arr()? {
            let ivec = |key: &str| -> Result<Vec<i32>> {
                g.get(key)?.arr()?.iter().map(|v| Ok(v.i64()? as i32)).collect()
            };
            let mut p_nonzero = Vec::new();
            for triple in g.get("p_nonzero")?.arr()? {
                let t = triple.arr()?;
                if t.len() != 3 {
                    bail!("bad p_nonzero triple");
                }
                p_nonzero.push((t[0].usize()?, t[1].usize()?, t[2].f64()? as f32));
            }
            mask_goldens.push(MaskGolden {
                method: g.get("method")?.str()?.to_string(),
                scheme: g.get("scheme")?.str()?.to_string(),
                chunk_lens: g.get("chunk_lens")?.usize_vec()?,
                comp_len: g.get("comp_len")?.usize()?,
                pool: g.get("pool")?.usize()?,
                input_len: g.get("input_len")?.usize()?,
                seq: g.get("seq")?.usize()?,
                mem_slots: g.get("mem_slots")?.usize()?,
                kind: ivec("kind")?,
                step: ivec("step")?,
                comp_slot: ivec("comp_slot")?,
                mask_rows: g
                    .get("mask_rows")?
                    .arr()?
                    .iter()
                    .map(|r| Ok(r.str()?.to_string()))
                    .collect::<Result<_>>()?,
                p_nonzero,
            });
        }

        Ok(Manifest {
            config_name: j.get("config_name")?.str()?.to_string(),
            dir: dir.to_path_buf(),
            model,
            scenario,
            base_layout,
            lora_layout,
            artifacts,
            mask_goldens,
        })
    }
}

/// Default artifact directory for a named config.
pub fn artifact_dir(config: &str) -> PathBuf {
    if let Ok(root) = std::env::var("CCM_ARTIFACTS") {
        return PathBuf::from(root).join(config);
    }
    // Walk up from CWD looking for artifacts/<config>/manifest.json so the
    // binary works from the repo root, rust/, or target dirs.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    for _ in 0..5 {
        let cand = dir.join("artifacts").join(config);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            break;
        }
    }
    PathBuf::from("artifacts").join(config)
}
