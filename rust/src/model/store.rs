//! Parameter store: flat f32 vectors + checkpoint I/O.
//!
//! The base model and the compression adapter (conditional LoRA +
//! `<COMP>` embeddings) each live in one flat buffer whose layout comes
//! from the manifest. Checkpoints are a simple versioned binary format
//! (magic, name, layout checksum, f32 LE payload) — no external deps.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::{Manifest, ParamLayout};
use crate::util::rng::Rng;

const MAGIC: &[u8; 8] = b"CCMCKPT1";

/// A flat parameter vector tied to a layout.
#[derive(Debug, Clone)]
pub struct ParamVec {
    pub data: Vec<f32>,
}

impl ParamVec {
    pub fn zeros(layout: &ParamLayout) -> ParamVec {
        ParamVec { data: vec![0.0; layout.total] }
    }

    /// Paper-style init: normal(0, 0.02) for matrices/embeddings, ones for
    /// norm scales, zeros for LoRA B (so the adapter starts as identity).
    pub fn init(layout: &ParamLayout, rng: &mut Rng, scale: f32) -> ParamVec {
        let mut v = vec![0.0f32; layout.total];
        for e in &layout.entries {
            let dst = &mut v[e.offset..e.offset + e.size];
            if e.name.contains("ln") || e.name.contains("norm") {
                dst.iter_mut().for_each(|x| *x = 1.0);
            } else if e.name.contains("lora_") && e.name.ends_with("_b") {
                // B starts at zero: LoRA contributes nothing until trained.
            } else {
                dst.iter_mut().for_each(|x| *x = rng.normal() * scale);
            }
        }
        ParamVec { data: v }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn l2_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

/// Everything a trained system needs at serve time.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub base: ParamVec,
    pub lora: ParamVec,
}

impl Checkpoint {
    pub fn init(manifest: &Manifest, seed: u64) -> Checkpoint {
        let mut rng = Rng::new(seed);
        Checkpoint {
            base: ParamVec::init(&manifest.base_layout, &mut rng, 0.02),
            lora: ParamVec::init(&manifest.lora_layout, &mut rng, 0.02),
        }
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        f.write_all(MAGIC)?;
        write_vec(&mut f, &self.base.data)?;
        write_vec(&mut f, &self.lora.data)?;
        Ok(())
    }

    pub fn load(path: &Path, manifest: &Manifest) -> Result<Checkpoint> {
        let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: not a CCM checkpoint");
        }
        let base = read_vec(&mut f)?;
        let lora = read_vec(&mut f)?;
        if base.len() != manifest.base_layout.total {
            bail!(
                "{path:?}: base params {} != manifest layout {} (stale checkpoint?)",
                base.len(),
                manifest.base_layout.total
            );
        }
        if lora.len() != manifest.lora_layout.total {
            bail!("{path:?}: lora params {} != layout {}", lora.len(), manifest.lora_layout.total);
        }
        Ok(Checkpoint { base: ParamVec { data: base }, lora: ParamVec { data: lora } })
    }
}

pub(crate) fn write_vec(f: &mut impl Write, v: &[f32]) -> Result<()> {
    f.write_all(&(v.len() as u64).to_le_bytes())?;
    let n_bytes = v.len() * 4;
    // SAFETY: reinterprets the f32 slice's own allocation as bytes —
    // same base pointer, exact byte length, u8 has no alignment or
    // validity requirements, and the borrow of v outlives `bytes`.
    // (f32 is LE on all supported platforms, fixed at read time.)
    let bytes: &[u8] = unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, n_bytes) };
    f.write_all(bytes)?;
    Ok(())
}

pub(crate) fn read_vec(f: &mut impl Read) -> Result<Vec<f32>> {
    let mut len8 = [0u8; 8];
    f.read_exact(&mut len8)?;
    let n = u64::from_le_bytes(len8) as usize;
    if n > (1 << 31) {
        bail!("checkpoint vector too large: {n}");
    }
    let mut bytes = vec![0u8; n * 4];
    f.read_exact(&mut bytes)?;
    let mut out = vec![0f32; n];
    for (i, c) in bytes.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
    }
    Ok(out)
}

/// Gather embedding rows from the flat base vector (used by the RMT
/// baseline, which feeds soft embeddings into `rmt_forward`).
pub fn gather_embeddings(
    base: &[f32],
    layout: &ParamLayout,
    tokens: &[i32],
    d_model: usize,
) -> Result<Vec<f32>> {
    let emb = layout.slice(base, "tok_emb")?;
    let vocab = layout.entry("tok_emb")?.shape[0];
    let mut out = vec![0f32; tokens.len() * d_model];
    for (i, &t) in tokens.iter().enumerate() {
        let t = t as usize;
        if t >= vocab {
            bail!("token id {t} out of vocab {vocab}");
        }
        out[i * d_model..(i + 1) * d_model].copy_from_slice(&emb[t * d_model..(t + 1) * d_model]);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::{LayoutEntry, ParamLayout};

    fn toy_layout() -> ParamLayout {
        ParamLayout {
            total: 10,
            entries: vec![
                LayoutEntry { name: "tok_emb".into(), offset: 0, size: 6, shape: vec![3, 2] },
                LayoutEntry { name: "ln1".into(), offset: 6, size: 2, shape: vec![2] },
                LayoutEntry {
                    name: "lora_q_b".into(),
                    offset: 8,
                    size: 2,
                    shape: vec![1, 2],
                },
            ],
        }
    }

    #[test]
    fn init_respects_kinds() {
        let lay = toy_layout();
        let v = ParamVec::init(&lay, &mut Rng::new(1), 0.02);
        assert!(v.data[..6].iter().any(|&x| x != 0.0));
        assert_eq!(&v.data[6..8], &[1.0, 1.0]);
        assert_eq!(&v.data[8..10], &[0.0, 0.0]); // lora B zero-init
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ccm-test-{}", std::process::id()));
        let path = dir.join("ck.bin");
        let lay = toy_layout();
        let ck = Checkpoint {
            base: ParamVec::init(&lay, &mut Rng::new(2), 0.02),
            lora: ParamVec { data: vec![1.5; 4] },
        };
        ck.save(&path).unwrap();
        // Fake manifest just for size checks.
        let mut mani_lay = lay.clone();
        mani_lay.total = 10;
        let manifest = fake_manifest(mani_lay.clone(), ParamLayout { total: 4, entries: vec![] });
        let back = Checkpoint::load(&path, &manifest).unwrap();
        assert_eq!(back.base.data, ck.base.data);
        assert_eq!(back.lora.data, ck.lora.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gather_embeddings_rows() {
        let lay = toy_layout();
        let base: Vec<f32> = (0..10).map(|x| x as f32).collect();
        let out = gather_embeddings(&base, &lay, &[2, 0], 2).unwrap();
        assert_eq!(out, vec![4.0, 5.0, 0.0, 1.0]);
        assert!(gather_embeddings(&base, &lay, &[9], 2).is_err());
    }

    fn fake_manifest(base: ParamLayout, lora: ParamLayout) -> crate::model::manifest::Manifest {
        use crate::model::manifest::*;
        Manifest {
            config_name: "toy".into(),
            dir: std::path::PathBuf::from("."),
            model: ModelConfig {
                name: "toy".into(),
                vocab: 3,
                d_model: 2,
                n_layers: 1,
                n_heads: 1,
                d_ff: 2,
                max_pos: 8,
                lora_rank: 1,
                lora_alpha: 2.0,
                pad_id: 0,
                bos_id: 1,
                sep_id: 2,
                comp_id: 3,
                d_head: 2,
            },
            scenario: ScenarioConfig {
                t_max: 2,
                chunk_max: 4,
                comp_len_max: 1,
                input_max: 4,
                seq_train: 16,
                mem_slots: 2,
                batch_train: 1,
                infer_batches: vec![1],
                decode_cache: 8,
                rmt_unroll: 1,
                rmt_mem: 1,
            },
            base_layout: base,
            lora_layout: lora,
            artifacts: vec![],
            mask_goldens: vec![],
        }
    }
}
