//! Versioned, checksummed session snapshots — the on-disk form of a
//! session's compressed context memory Mem(t).
//!
//! This extends the checkpoint tensor serialization of
//! [`super::store`] (`write_vec`, shared here) into a self-contained
//! [`SessionSnapshot`]: magic + version + strategy kind + online step
//! `t` / position cursor + the KV tensors of the memory store + the
//! strategy's raw-token retention state + a trailing CRC-32. The server
//! hibernation tier (`server::hibernate`) spills cold sessions in this
//! format and rehydrates them on the next touch; the same artifact is
//! the unit a future cross-host replication channel would ship.
//!
//! ## Failure discipline
//!
//! Decoding mirrors the shard-IPC codec's property-test contract:
//! arbitrary truncation and arbitrary byte corruption must fail with a
//! clean `Err`, never a panic, a huge allocation, or a torn value.
//! Every length field is bounds-checked before its allocation, tensor
//! lengths must match the declared dimensions exactly, and the CRC over
//! the entire body catches any flip the structural checks let through.
//! Readers may deliver bytes in arbitrarily small chunks (`read_exact`
//! loops), so streaming from a socket or a file behaves identically.

use std::io::Read;

use anyhow::{bail, Context, Result};

use crate::compress::strategy::{StrategyKind, StrategyState};
use crate::masks::MergeScheme;
use crate::memory::window::StreamWindow;
use crate::memory::{MemBuffers, MemoryStore, UpdateKind};
use crate::model::store::write_vec;

/// Snapshot file magic (8 bytes, versioned separately below).
pub const SNAP_MAGIC: &[u8; 8] = b"CCMSNAP1";
/// Current snapshot format version. Decoders reject anything else —
/// the hibernation tier treats that exactly like a missing snapshot.
pub const SNAP_VERSION: u32 = 1;

/// Hard caps a decoder enforces BEFORE allocating: a corrupt length
/// field must fail cleanly, not reserve gigabytes. Generous against
/// every real manifest (d_model·layers·slots products sit far below).
const MAX_ID_BYTES: usize = 4096;
const MAX_DIM: u64 = 1 << 16;
const MAX_TENSOR_ELEMS: u64 = 1 << 26; // 64M f32 = 256 MB per tensor
const MAX_TOKENS: u64 = 1 << 24;

/// Everything needed to reconstruct a session's memory state after a
/// hibernate/rehydrate cycle (wall-clock fields like `last_used` are
/// re-seeded at restore time — a rehydrated session was just touched).
#[derive(Debug, Clone)]
pub struct SessionSnapshot {
    pub id: String,
    pub strategy: StrategyKind,
    /// Online time step t (chunks absorbed) at spill time.
    pub t: u64,
    /// Next absolute position id of the memory store.
    pub pos_cursor: u64,
    /// Creation order stamp (monotone per shard) — preserved so
    /// eviction order survives a hibernate cycle.
    pub created: u64,
    pub raw_context_tokens: u64,
    pub dropped_tokens: u64,
    /// Mem(t): the compressed KV tensors and their update policy.
    pub mem: MemoryStore,
    /// Strategy-owned raw-token retention (window / full tail).
    pub state: StrategyState,
}

impl SessionSnapshot {
    /// Strategy-aware live KV bytes this snapshot represents — the
    /// quantity the hibernation tier subtracts from the hot budget.
    pub fn kv_bytes(&self) -> usize {
        let per_tok = 2 * self.mem.buffers.layers * self.mem.buffers.d_model * 4;
        self.mem.kv_bytes() + self.state.raw_kv_tokens() * per_tok
    }

    /// Encode to the versioned on-disk format (trailing CRC included).
    pub fn encode(&self) -> Result<Vec<u8>> {
        if self.id.len() > MAX_ID_BYTES {
            bail!("session id too long to snapshot: {} bytes", self.id.len());
        }
        if !state_matches(self.strategy, &self.state) {
            bail!("snapshot strategy {:?} does not match its state", self.strategy);
        }
        let b = &self.mem.buffers;
        let elems = b.layers * b.slots * b.d_model;
        if b.k.len() != elems || b.v.len() != elems {
            bail!("memory tensors disagree with dims: {} vs {elems}", b.k.len());
        }
        let mut out = Vec::with_capacity(128 + self.id.len() + elems * 8);
        out.extend_from_slice(SNAP_MAGIC);
        out.extend_from_slice(&SNAP_VERSION.to_le_bytes());
        out.push(self.strategy.wire());
        out.extend_from_slice(&(self.id.len() as u32).to_le_bytes());
        out.extend_from_slice(self.id.as_bytes());
        for v in
            [self.t, self.pos_cursor, self.created, self.raw_context_tokens, self.dropped_tokens]
        {
            out.extend_from_slice(&v.to_le_bytes());
        }
        match &self.mem.kind {
            UpdateKind::Concat => out.push(0),
            UpdateKind::Merge(MergeScheme::Avg) => out.push(1),
            UpdateKind::Merge(MergeScheme::Ema(a)) => {
                out.push(2);
                out.extend_from_slice(&a.to_le_bytes());
            }
        }
        for v in [
            self.mem.t as u64,
            self.mem.comp_len as u64,
            b.layers as u64,
            b.slots as u64,
            b.d_model as u64,
            b.len as u64,
        ] {
            out.extend_from_slice(&v.to_le_bytes());
        }
        write_vec(&mut out, &b.k)?;
        write_vec(&mut out, &b.v)?;
        match &self.state {
            StrategyState::Ccm => out.push(0),
            StrategyState::Window(w) => {
                out.push(1);
                for v in [w.max_kv as u64, w.n_sink as u64, w.seen] {
                    out.extend_from_slice(&v.to_le_bytes());
                }
                write_tokens(&mut out, &w.sink);
                write_tokens(&mut out, &w.window);
            }
            StrategyState::Full(tail) => {
                out.push(2);
                write_tokens(&mut out, tail);
            }
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        Ok(out)
    }

    /// Decode a complete snapshot; trailing garbage is corruption.
    pub fn decode(bytes: &[u8]) -> Result<SessionSnapshot> {
        let mut r = bytes;
        let snap = Self::read_from(&mut r)?;
        if !r.is_empty() {
            bail!("snapshot has {} trailing bytes", r.len());
        }
        Ok(snap)
    }

    /// Decode from a reader (chunked delivery is fine: every field goes
    /// through `read_exact`). Leaves the reader positioned just past
    /// the trailing CRC.
    pub fn read_from(r: &mut impl Read) -> Result<SessionSnapshot> {
        let mut cr = CrcReader { inner: r, crc: 0xFFFF_FFFF };
        let mut magic = [0u8; 8];
        cr.read_exact(&mut magic).context("snapshot magic")?;
        if &magic != SNAP_MAGIC {
            bail!("not a CCM session snapshot");
        }
        let version = r_u32(&mut cr)?;
        if version != SNAP_VERSION {
            bail!("unsupported snapshot version {version} (expected {SNAP_VERSION})");
        }
        let strategy = StrategyKind::from_wire(r_u8(&mut cr)?)?;
        let id_len = r_u32(&mut cr)? as usize;
        if id_len > MAX_ID_BYTES {
            bail!("snapshot session id length {id_len} exceeds {MAX_ID_BYTES}");
        }
        let mut id_bytes = vec![0u8; id_len];
        cr.read_exact(&mut id_bytes).context("snapshot session id")?;
        let id = String::from_utf8(id_bytes).context("snapshot session id utf-8")?;
        let t = r_u64(&mut cr)?;
        let pos_cursor = r_u64(&mut cr)?;
        let created = r_u64(&mut cr)?;
        let raw_context_tokens = r_u64(&mut cr)?;
        let dropped_tokens = r_u64(&mut cr)?;
        let kind = match r_u8(&mut cr)? {
            0 => UpdateKind::Concat,
            1 => UpdateKind::Merge(MergeScheme::Avg),
            2 => {
                let a = f32::from_le_bytes(r_u32(&mut cr)?.to_le_bytes());
                if !a.is_finite() {
                    bail!("snapshot EMA coefficient is not finite");
                }
                UpdateKind::Merge(MergeScheme::Ema(a))
            }
            other => bail!("unknown memory update kind byte {other}"),
        };
        let mem_t = r_u64(&mut cr)?;
        let comp_len = r_u64(&mut cr)?;
        let layers = r_u64(&mut cr)?;
        let slots = r_u64(&mut cr)?;
        let d_model = r_u64(&mut cr)?;
        let len = r_u64(&mut cr)?;
        if layers == 0 || layers > MAX_DIM || slots > MAX_DIM || d_model == 0 || d_model > MAX_DIM
        {
            bail!("snapshot memory dims out of range: L={layers} M={slots} D={d_model}");
        }
        let elems = layers * slots * d_model;
        if elems > MAX_TENSOR_ELEMS {
            bail!("snapshot memory tensor too large: {elems} elements");
        }
        if len > slots || comp_len > MAX_DIM || mem_t > u64::MAX / 2 {
            bail!("snapshot memory header inconsistent: len={len} slots={slots}");
        }
        let k = read_tensor(&mut cr, elems as usize)?;
        let v = read_tensor(&mut cr, elems as usize)?;
        let mem = MemoryStore {
            buffers: MemBuffers {
                k,
                v,
                len: len as usize,
                layers: layers as usize,
                slots: slots as usize,
                d_model: d_model as usize,
            },
            kind,
            t: mem_t as usize,
            comp_len: comp_len as usize,
        };
        let state = match r_u8(&mut cr)? {
            0 => StrategyState::Ccm,
            1 => {
                let max_kv = r_u64(&mut cr)?;
                let n_sink = r_u64(&mut cr)?;
                let seen = r_u64(&mut cr)?;
                if max_kv > MAX_TOKENS || n_sink > max_kv {
                    bail!("snapshot window header inconsistent: kv={max_kv} sink={n_sink}");
                }
                let sink = read_tokens(&mut cr, "window sink")?;
                let window = read_tokens(&mut cr, "window tail")?;
                if sink.len() as u64 > n_sink || (sink.len() + window.len()) as u64 > max_kv {
                    bail!("snapshot window exceeds its own budget");
                }
                let mut w = StreamWindow::streaming_llm(max_kv as usize, n_sink as usize);
                w.sink = sink;
                w.window = window;
                w.seen = seen;
                StrategyState::Window(w)
            }
            2 => StrategyState::Full(read_tokens(&mut cr, "full tail")?),
            other => bail!("unknown strategy state byte {other}"),
        };
        if !state_matches(strategy, &state) {
            bail!("snapshot state does not match strategy {:?}", strategy);
        }
        let computed = cr.crc ^ 0xFFFF_FFFF;
        let mut tail = [0u8; 4];
        cr.inner.read_exact(&mut tail).context("snapshot crc")?;
        let stored = u32::from_le_bytes(tail);
        if stored != computed {
            bail!("snapshot CRC mismatch: stored {stored:#010x}, computed {computed:#010x}");
        }
        Ok(SessionSnapshot {
            id,
            strategy,
            t,
            pos_cursor,
            created,
            raw_context_tokens,
            dropped_tokens,
            mem,
            state,
        })
    }
}

fn state_matches(strategy: StrategyKind, state: &StrategyState) -> bool {
    matches!(
        (strategy, state),
        (StrategyKind::Ccm, StrategyState::Ccm)
            | (StrategyKind::SlidingWindow, StrategyState::Window(_))
            | (StrategyKind::NoCompress, StrategyState::Full(_))
    )
}

fn write_tokens(out: &mut Vec<u8>, toks: &[i32]) {
    out.extend_from_slice(&(toks.len() as u32).to_le_bytes());
    for t in toks {
        out.extend_from_slice(&t.to_le_bytes());
    }
}

fn read_tokens(r: &mut impl Read, what: &str) -> Result<Vec<i32>> {
    let n = r_u32(r)? as u64;
    if n > MAX_TOKENS {
        bail!("snapshot {what} token count {n} exceeds {MAX_TOKENS}");
    }
    let mut bytes = vec![0u8; n as usize * 4];
    r.read_exact(&mut bytes).with_context(|| format!("snapshot {what} tokens"))?;
    Ok(bytes.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Bounded counterpart of `store::read_vec`: the element count is
/// dictated by the already-validated dims, so a corrupt length field
/// can never trigger an oversized allocation.
fn read_tensor(r: &mut impl Read, expect: usize) -> Result<Vec<f32>> {
    let n = r_u64(r)?;
    if n != expect as u64 {
        bail!("snapshot tensor length {n} disagrees with dims ({expect})");
    }
    let mut bytes = vec![0u8; expect * 4];
    r.read_exact(&mut bytes).context("snapshot tensor payload")?;
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

fn r_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b).context("snapshot u8")?;
    Ok(b[0])
}

fn r_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b).context("snapshot u32")?;
    Ok(u32::from_le_bytes(b))
}

fn r_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b).context("snapshot u64")?;
    Ok(u64::from_le_bytes(b))
}

// ---------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected) — hand-rolled, no dependencies.

const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

fn crc32_update(mut crc: u32, bytes: &[u8]) -> u32 {
    for &b in bytes {
        crc = CRC_TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc
}

/// CRC-32 of a complete buffer (init 0xFFFFFFFF, final xor).
pub fn crc32(bytes: &[u8]) -> u32 {
    crc32_update(0xFFFF_FFFF, bytes) ^ 0xFFFF_FFFF
}

/// Reader adapter that folds everything it yields into a running CRC,
/// so streaming decode verifies exactly the bytes it consumed.
struct CrcReader<'a, R: Read> {
    inner: &'a mut R,
    crc: u32,
}

impl<R: Read> Read for CrcReader<'_, R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.crc = crc32_update(self.crc, &buf[..n]);
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// A reader that splits its payload into two reads at `split`,
    /// then trickles one byte at a time (exercises read_exact loops).
    struct SplitReader {
        data: Vec<u8>,
        pos: usize,
        split: usize,
    }

    impl Read for SplitReader {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.data.len() || buf.is_empty() {
                return Ok(0);
            }
            let chunk = if self.pos < self.split { self.split - self.pos } else { 1 };
            let n = chunk.min(buf.len()).min(self.data.len() - self.pos);
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    fn random_snapshot(rng: &mut Rng, kind: StrategyKind) -> SessionSnapshot {
        let layers = rng.range(1, 4);
        let slots = rng.range(1, 9);
        let d_model = rng.range(1, 9);
        let comp_len = rng.range(1, slots + 1);
        let elems = layers * slots * d_model;
        let mem_kind = match rng.range(0, 3) {
            0 => UpdateKind::Concat,
            1 => UpdateKind::Merge(MergeScheme::Avg),
            _ => UpdateKind::Merge(MergeScheme::Ema(0.25 + rng.range(0, 50) as f32 / 100.0)),
        };
        let len = rng.range(0, slots + 1);
        let mem = MemoryStore {
            buffers: MemBuffers {
                k: (0..elems).map(|_| rng.normal()).collect(),
                v: (0..elems).map(|_| rng.normal()).collect(),
                len,
                layers,
                slots,
                d_model,
            },
            kind: mem_kind,
            t: rng.range(0, 100),
            comp_len,
        };
        let state = match kind {
            StrategyKind::Ccm => StrategyState::Ccm,
            StrategyKind::SlidingWindow => {
                let n_sink = rng.range(0, 4);
                let max_kv = n_sink + rng.range(1, 16);
                let mut w = StreamWindow::streaming_llm(max_kv, n_sink);
                for t in 0..rng.range(0, 2 * max_kv) {
                    w.push(t as i32);
                }
                StrategyState::Window(w)
            }
            StrategyKind::NoCompress => {
                StrategyState::Full((0..rng.range(0, 32)).map(|x| x as i32).collect())
            }
        };
        SessionSnapshot {
            id: format!("user-{}", rng.range(0, 1000)),
            strategy: kind,
            t: rng.range(0, 1000) as u64,
            pos_cursor: rng.range(0, 10_000) as u64,
            created: rng.range(1, 1_000_000) as u64,
            raw_context_tokens: rng.range(0, 10_000) as u64,
            dropped_tokens: rng.range(0, 100) as u64,
            mem,
            state,
        }
    }

    /// Minimal-dims snapshot for the O(bytes^2) sweep tests below —
    /// keeps them fast under the Miri CI filter.
    fn tiny_snapshot(kind: StrategyKind) -> SessionSnapshot {
        let elems = 4; // layers 1, slots 2, d_model 2
        let mem = MemoryStore {
            buffers: MemBuffers {
                k: (0..elems).map(|x| x as f32).collect(),
                v: (0..elems).map(|x| -(x as f32)).collect(),
                len: 2,
                layers: 1,
                slots: 2,
                d_model: 2,
            },
            kind: UpdateKind::Concat,
            t: 3,
            comp_len: 2,
        };
        let state = match kind {
            StrategyKind::Ccm => StrategyState::Ccm,
            StrategyKind::SlidingWindow => {
                let mut w = StreamWindow::streaming_llm(4, 1);
                for t in 0..6 {
                    w.push(t);
                }
                StrategyState::Window(w)
            }
            StrategyKind::NoCompress => StrategyState::Full(vec![7, 8, 9]),
        };
        SessionSnapshot {
            id: "tiny".into(),
            strategy: kind,
            t: 3,
            pos_cursor: 12,
            created: 5,
            raw_context_tokens: 9,
            dropped_tokens: 2,
            mem,
            state,
        }
    }

    fn assert_equivalent(a: &SessionSnapshot, b: &SessionSnapshot) -> Result<(), String> {
        crate::prop_assert!(a.id == b.id, "id {} != {}", a.id, b.id);
        crate::prop_assert!(a.strategy == b.strategy, "strategy mismatch");
        crate::prop_assert!(
            (a.t, a.pos_cursor, a.created) == (b.t, b.pos_cursor, b.created),
            "counters mismatch"
        );
        crate::prop_assert!(
            (a.raw_context_tokens, a.dropped_tokens) == (b.raw_context_tokens, b.dropped_tokens),
            "token accounting mismatch"
        );
        crate::prop_assert!(a.mem.t == b.mem.t && a.mem.comp_len == b.mem.comp_len, "mem header");
        crate::prop_assert!(
            a.mem.buffers.k == b.mem.buffers.k && a.mem.buffers.v == b.mem.buffers.v,
            "mem tensors differ"
        );
        crate::prop_assert!(
            a.mem.buffers.len == b.mem.buffers.len
                && a.mem.buffers.layers == b.mem.buffers.layers
                && a.mem.buffers.slots == b.mem.buffers.slots
                && a.mem.buffers.d_model == b.mem.buffers.d_model,
            "mem dims differ"
        );
        crate::prop_assert!(a.kv_bytes() == b.kv_bytes(), "kv accounting differs");
        match (&a.state, &b.state) {
            (StrategyState::Ccm, StrategyState::Ccm) => {}
            (StrategyState::Window(x), StrategyState::Window(y)) => {
                crate::prop_assert!(
                    x.sink == y.sink && x.window == y.window && x.seen == y.seen,
                    "window state differs"
                );
                crate::prop_assert!(
                    x.max_kv == y.max_kv && x.n_sink == y.n_sink,
                    "window budget differs"
                );
            }
            (StrategyState::Full(x), StrategyState::Full(y)) => {
                crate::prop_assert!(x == y, "full tail differs");
            }
            _ => return Err("state variant changed across round-trip".into()),
        }
        Ok(())
    }

    #[test]
    fn roundtrip_over_random_sessions_per_strategy() {
        crate::util::proptest::check("snapshot-roundtrip", 30, |rng| {
            for kind in StrategyKind::ALL {
                let snap = random_snapshot(rng, kind);
                let bytes = snap.encode().map_err(|e| format!("encode: {e:#}"))?;
                let back = SessionSnapshot::decode(&bytes).map_err(|e| format!("decode: {e:#}"))?;
                assert_equivalent(&snap, &back)?;
            }
            Ok(())
        });
    }

    #[test]
    fn split_at_every_byte_decodes_identically() {
        for kind in StrategyKind::ALL {
            let snap = tiny_snapshot(kind);
            let bytes = snap.encode().unwrap();
            for split in 0..=bytes.len() {
                let mut r = SplitReader { data: bytes.clone(), pos: 0, split };
                let back = SessionSnapshot::read_from(&mut r)
                    .unwrap_or_else(|e| panic!("split {split}: {e:#}"));
                assert_eq!(back.id, snap.id, "split {split}");
                assert_eq!(back.t, snap.t, "split {split}");
            }
        }
    }

    #[test]
    fn truncation_at_every_byte_fails_cleanly() {
        let snap = tiny_snapshot(StrategyKind::SlidingWindow);
        let bytes = snap.encode().unwrap();
        for cut in 0..bytes.len() {
            let err = SessionSnapshot::decode(&bytes[..cut]);
            assert!(err.is_err(), "truncation at {cut}/{} must fail", bytes.len());
        }
    }

    #[test]
    fn any_single_byte_flip_is_detected() {
        for kind in StrategyKind::ALL {
            let snap = tiny_snapshot(kind);
            let bytes = snap.encode().unwrap();
            for i in 0..bytes.len() {
                let mut bad = bytes.clone();
                bad[i] ^= 0x5A;
                assert!(
                    SessionSnapshot::decode(&bad).is_err(),
                    "flip at {i}/{} slipped through ({})",
                    bytes.len(),
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn wrong_version_and_magic_are_rejected() {
        let snap = tiny_snapshot(StrategyKind::Ccm);
        let bytes = snap.encode().unwrap();
        // Future version: refused by name before anything is read.
        let mut v2 = bytes.clone();
        v2[8..12].copy_from_slice(&2u32.to_le_bytes());
        let err = SessionSnapshot::decode(&v2).unwrap_err();
        assert!(err.to_string().contains("version"), "got: {err:#}");
        // Checkpoint magic is a different artifact, not a version skew.
        let mut ck = bytes.clone();
        ck[..8].copy_from_slice(b"CCMCKPT1");
        assert!(SessionSnapshot::decode(&ck).is_err());
        // Trailing garbage after a valid snapshot is corruption.
        let mut long = bytes.clone();
        long.push(0);
        assert!(SessionSnapshot::decode(&long).is_err());
    }

    #[test]
    fn crc32_matches_known_vector() {
        // IEEE 802.3 check value for "123456789".
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
