//! Model-side substrate: the manifest emitted by the compile path, flat
//! parameter storage + checkpoints, and Adam optimizer state buffers.

pub mod manifest;
pub mod snapshot;
pub mod store;

pub use manifest::{artifact_dir, Manifest};
pub use store::{Checkpoint, ParamVec};

/// Adam moment buffers threaded through the train-step artifacts.
#[derive(Debug, Clone)]
pub struct AdamState {
    pub mu: Vec<f32>,
    pub nu: Vec<f32>,
    pub step: i32,
}

impl AdamState {
    pub fn new(n: usize) -> AdamState {
        AdamState { mu: vec![0.0; n], nu: vec![0.0; n], step: 0 }
    }
}

/// Cosine learning-rate schedule with linear warmup (paper recipe:
/// cosine decay; warmup stabilises the tiny-model runs).
pub fn cosine_lr(step: usize, total: usize, base: f32, warmup: usize) -> f32 {
    if total == 0 {
        return base;
    }
    if step < warmup {
        return base * (step as f32 + 1.0) / warmup as f32;
    }
    let t = (step - warmup) as f32 / (total.saturating_sub(warmup)).max(1) as f32;
    0.5 * base * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_schedule_shape() {
        let base = 3e-4;
        assert!(cosine_lr(0, 100, base, 10) < base * 0.2);
        let mid = cosine_lr(55, 100, base, 10);
        assert!(mid < base && mid > 0.0);
        assert!(cosine_lr(99, 100, base, 10) < base * 0.1);
        // Monotone decay after warmup.
        let a = cosine_lr(20, 100, base, 10);
        let b = cosine_lr(60, 100, base, 10);
        assert!(a > b);
    }
}
