//! `ccm loadgen` — open-loop multi-tenant traffic replay of the
//! paper's workloads against a live serving instance.
//!
//! The paper evaluates compressed context memory on four online
//! settings (conversation / LaMP personalization / MetaICL multi-task
//! / PG19-style streaming); `rust/src/datagen/` synthesizes all four.
//! This module replays them as *serving traffic*: a population of
//! concurrent synthetic users — mixed across scenarios by weight
//! ([`Mix`]), with heavy-tailed session lengths
//! ([`heavy_tail_len`]) and reconnect churn — drives a running `ccm
//! serve` endpoint over the real JSON-lines client protocol.
//! docs/SCENARIOS.md is the operator handbook mapping each paper
//! evaluation to its loadgen scenario and flags.
//!
//! A mix entry may pin an admission tier (`--mix
//! dialog@ccm=3,dialog@none=1`): those users send the `op:"context"`
//! `strategy` field, so a single replay A/Bs compressed-vs-full
//! serving under identical load, with separate latency/refusal
//! buckets — and report rows — per (workload, tier) population
//! ([`Tenant`]).
//!
//! ## Open-loop pacing (no coordinated omission)
//!
//! Every request has a pre-computed scheduled send time (per-user
//! exponential inter-arrival gaps around the aggregate `--rate`). A
//! late request is sent immediately but NEVER rescheduled, and its
//! latency is measured from the *scheduled* time — so when the server
//! falls behind, the backlog lands in the reported tail instead of
//! silently stretching the schedule (the classic closed-loop
//! coordinated-omission trap). Per-session ordering still holds:
//! each user's requests go down one connection, sequentially.
//!
//! ## Refusals are not latency samples
//!
//! Admission refusals (`overloaded`, `shutting_down`), connection
//! refusals (`too_many_connections` — which the reactor's
//! `REFUSAL_LINGER` path sends on accept), `shard_unavailable` and
//! `timeout` replies are counted in a separate refusal bucket per
//! scenario ([`Bucket`]), broken down by kind. They NEVER contribute
//! to the latency pool: a tail percentile only summarizes requests
//! the server actually served.
//!
//! ## Live compression-quality sampling
//!
//! Every `--quality-every`-th user ends its session with a scored
//! probe: a short greedy continuation generated over the session's
//! *compressed* memory (repeated `query` round trips) is scored with
//! ROUGE-L ([`crate::eval::rouge`]) against the generator's
//! full-context reference continuation, and the session's live
//! compressed-KV bytes (from context acks) sit next to the analytic
//! full-context and CCM-concat peaks from
//! [`crate::eval::memacct`] — the paper's quality-vs-memory trade-off,
//! observed on live traffic. Under the deterministic SimCompute
//! backend the generation is an echo and ROUGE-L is a plumbing-level
//! signal; under a trained engine it is the real Table-7 metric.
//!
//! Results print as a per-scenario table and emit in the
//! [`Report`] schema (`--emit`), so `ccm bench --compare` composes
//! with the BENCH_<n>.json trajectory (docs/BENCH.md); the pinned
//! [`bench_scenario`] joins `ccm bench` as `loadgen-mixed`, and the
//! pinned [`bench_idle_spill_scenario`] as `loadgen-idle-spill` — an
//! idle-heavy population against a hibernating server, tracking the
//! spill/rehydrate counters on the serving path. The self-serve path
//! takes `--hibernate-dir DIR [--hibernate-after-ms 200]` to replay
//! any scenario against a hibernating server (docs/SCENARIOS.md).

use std::collections::BTreeMap;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::compress::{Compute, StrategyKind};
use crate::datagen::stream::StreamGen;
use crate::datagen::{self, OnlineDataset, Split};
use crate::eval::{memacct, rouge};
use crate::masks::Method;
use crate::model::manifest::ModelConfig;
use crate::model::Manifest;
use crate::server::{fmt_tokens, serve_sharded, BackendFactory, Client};
use crate::util::bench::{percentile_mille, print_table, Report, Scenario};
use crate::util::cli::Args;
use crate::util::json::{escape, Json};
use crate::util::rng::Rng;

/// Connection-level retry budget per scheduled request: reconnect and
/// resend after an EOF or a `too_many_connections` accept refusal.
/// Admission refusals are final (open-loop: never pile on).
const EVENT_ATTEMPTS: usize = 3;
/// Connect attempts before a request counts as lost.
const CONNECT_ATTEMPTS: usize = 5;
/// Backoff between connection-level retries.
const RETRY_BACKOFF: Duration = Duration::from_millis(20);
/// Stack per synthetic-user thread: the hot loop is shallow (no
/// recursion, heap-allocated plans), so default 8 MiB stacks would
/// only waste address space at thousands of users.
const USER_STACK: usize = 128 * 1024;
/// Greedy-generation cap per quality probe (round trips per sample).
const GEN_BUDGET: usize = 8;

// ---------------------------------------------------------------------
// Population shape: workloads, mixes, session-length distribution.

/// One paper workload a synthetic user can replay.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Workload {
    /// Conversation (DailyDialog-style): context is the dialogue so
    /// far, one turn per time step.
    Dialog,
    /// LaMP personalization: context is the user profile.
    Lamp,
    /// MetaICL multi-task ICL: context is the demonstration set.
    MetaIcl,
    /// PG19-style unbounded stream (not an [`OnlineDataset`]; driven
    /// through [`StreamGen`] directly).
    Stream,
}

impl Workload {
    pub const ALL: [Workload; 4] =
        [Workload::Dialog, Workload::Lamp, Workload::MetaIcl, Workload::Stream];

    pub fn name(self) -> &'static str {
        match self {
            Workload::Dialog => "dialog",
            Workload::Lamp => "lamp",
            Workload::MetaIcl => "metaicl",
            Workload::Stream => "stream",
        }
    }

    pub fn parse(s: &str) -> Result<Workload> {
        match s {
            "dialog" => Ok(Workload::Dialog),
            "lamp" => Ok(Workload::Lamp),
            "metaicl" => Ok(Workload::MetaIcl),
            "stream" => Ok(Workload::Stream),
            other => bail!("unknown workload {other:?} (dialog|lamp|metaicl|stream)"),
        }
    }
}

/// One population slice: a workload plus the admission tier its users
/// request. `strategy: None` omits the `op:"context"` field so the
/// session rides the server's default tier (the pre-tiering behavior).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Tenant {
    pub workload: Workload,
    pub strategy: Option<StrategyKind>,
}

impl Tenant {
    pub fn untiered(workload: Workload) -> Tenant {
        Tenant { workload, strategy: None }
    }

    /// Row label: `dialog` for an untiered slice, `dialog@ccm` for a
    /// pinned tier (the same grammar `Mix::parse` accepts).
    pub fn name(&self) -> String {
        match self.strategy {
            Some(k) => format!("{}@{}", self.workload.name(), k.name()),
            None => self.workload.name().to_string(),
        }
    }
}

/// Weighted scenario population: how `--users` splits across
/// workloads — and, optionally, across admission tiers. Parsed from
/// `--scenario mixed|<name>` or an explicit `--mix
/// dialog=4,metaicl=2,...` weight list where each entry may pin a
/// tier: `dialog@ccm=3,dialog@none=1`.
#[derive(Debug, Clone)]
pub struct Mix {
    pub weights: Vec<(Tenant, f32)>,
}

impl Mix {
    /// The default mixed population: conversation-heavy, with
    /// personalization and multi-task ICL side traffic and a thin
    /// stream of long-lived readers (docs/SCENARIOS.md). Untiered:
    /// every session serves under the server's default strategy.
    pub fn mixed() -> Mix {
        Mix {
            weights: vec![
                (Tenant::untiered(Workload::Dialog), 4.0),
                (Tenant::untiered(Workload::MetaIcl), 2.0),
                (Tenant::untiered(Workload::Lamp), 2.0),
                (Tenant::untiered(Workload::Stream), 1.0),
            ],
        }
    }

    pub fn single(wl: Workload) -> Mix {
        Mix { weights: vec![(Tenant::untiered(wl), 1.0)] }
    }

    /// `"mixed"`, a single workload name, or `name[@tier]=weight`
    /// pairs (comma-separated, weights are relative; the tier names
    /// are [`StrategyKind::parse`]'s).
    pub fn parse(spec: &str) -> Result<Mix> {
        if spec == "mixed" {
            return Ok(Mix::mixed());
        }
        if !spec.contains('=') {
            return Ok(Mix::single(Workload::parse(spec)?));
        }
        let mut weights = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((name, w)) = part.split_once('=') else {
                bail!("bad mix entry {part:?} (want name[@tier]=weight)");
            };
            let tenant = match name.trim().split_once('@') {
                Some((wl, tier)) => Tenant {
                    workload: Workload::parse(wl.trim())?,
                    strategy: Some(StrategyKind::parse(tier.trim())?),
                },
                None => Tenant::untiered(Workload::parse(name.trim())?),
            };
            let weight: f32 =
                w.trim().parse().map_err(|_| anyhow!("bad mix weight {w:?} in {part:?}"))?;
            if weight < 0.0 {
                bail!("negative mix weight in {part:?}");
            }
            weights.push((tenant, weight));
        }
        if !weights.iter().any(|(_, w)| *w > 0.0) {
            bail!("mix {spec:?} has no positive weight");
        }
        Ok(Mix { weights })
    }

    /// Deterministic largest-remainder apportionment of `users` across
    /// the weighted tenants (counts sum exactly to `users`).
    pub fn assign(&self, users: usize) -> Vec<Tenant> {
        let active: Vec<(Tenant, f32)> =
            self.weights.iter().copied().filter(|(_, w)| *w > 0.0).collect();
        if users == 0 || active.is_empty() {
            return Vec::new();
        }
        let total: f64 = active.iter().map(|(_, w)| *w as f64).sum();
        let quotas: Vec<f64> =
            active.iter().map(|(_, w)| users as f64 * (*w as f64) / total).collect();
        let mut counts: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
        let mut order: Vec<usize> = (0..active.len()).collect();
        order.sort_by(|&a, &b| {
            let fa = quotas[a] - quotas[a].floor();
            let fb = quotas[b] - quotas[b].floor();
            fb.partial_cmp(&fa).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
        });
        let mut left = users - counts.iter().sum::<usize>();
        for &i in &order {
            if left == 0 {
                break;
            }
            counts[i] += 1;
            left -= 1;
        }
        let mut out = Vec::with_capacity(users);
        for (i, (tenant, _)) in active.iter().enumerate() {
            for _ in 0..counts[i] {
                out.push(*tenant);
            }
        }
        out
    }
}

/// Bounded-Pareto session length: most sessions are short, a heavy
/// tail runs to the cap — the multi-tenant shape where a few users
/// accumulate deep compressed memory while most stay shallow.
pub fn heavy_tail_len(rng: &mut Rng, lo: usize, hi: usize, alpha: f64) -> usize {
    let lo = lo.max(1);
    if hi <= lo {
        return hi.max(1);
    }
    let u = rng.f64().min(0.999_999);
    let x = lo as f64 / (1.0 - u).powf(1.0 / alpha);
    (x.floor() as usize).clamp(lo, hi)
}

/// Loadgen run parameters (`LoadSpec::from_args` maps the CLI flags).
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Concurrent synthetic users (one session + connection each).
    pub users: usize,
    /// Scenario population weights.
    pub mix: Mix,
    /// Aggregate target request rate (req/s) across the population;
    /// per-user inter-arrival gaps are exponential around it.
    pub rate: f32,
    pub seed: u64,
    /// Probability of dropping + reopening the connection after an
    /// event (reconnect churn; the session id — and so Mem(t) — stays).
    pub churn: f32,
    /// Score every Nth user's session with the quality probe (0 = off).
    pub quality_every: usize,
    /// Session arrivals spread uniformly over this ramp window.
    pub ramp_secs: f64,
    /// Session-length cap for the unbounded stream workload.
    pub stream_len_max: usize,
    /// `topk` for scheduled query requests.
    pub topk: usize,
}

impl LoadSpec {
    pub fn from_args(args: &Args) -> Result<LoadSpec> {
        let scenario = args.str("scenario", "mixed");
        let mix = match args.flags.get("mix") {
            Some(m) => Mix::parse(m)?,
            None => Mix::parse(&scenario)?,
        };
        Ok(LoadSpec {
            users: args.usize("users", 256)?,
            mix,
            rate: args.f32("rate", 800.0)?,
            seed: args.u64("seed", 7)?,
            churn: args.f32("churn", 0.05)?,
            quality_every: args.usize("quality-every", 8)?,
            ramp_secs: args.u64("ramp-ms", 500)? as f64 / 1e3,
            stream_len_max: args.usize("stream-len", 16)?,
            topk: args.usize("topk", 3)?,
        })
    }
}

// ---------------------------------------------------------------------
// Per-user replay plans (built up front, deterministically).

#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    Context { tokens: Vec<i32> },
    Query { tokens: Vec<i32> },
}

/// One scheduled request.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Scheduled send offset from the run epoch.
    pub at: Duration,
    pub kind: EventKind,
    /// Drop the connection after this event (reconnect churn).
    pub churn_after: bool,
}

/// The quality probe appended to a sampled user's session: greedy
/// continuation of `input` over compressed memory, scored against the
/// generator's full-context reference continuation `target`.
#[derive(Debug, Clone, PartialEq)]
pub struct QualityProbe {
    pub input: Vec<i32>,
    pub target: Vec<i32>,
}

/// One synthetic user's full replay schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct UserPlan {
    pub user: usize,
    pub tenant: Tenant,
    pub session: String,
    pub events: Vec<Event>,
    pub quality: Option<QualityProbe>,
}

/// Build every user's schedule: deterministic in (`spec.seed`, user
/// index), so a replay is reproducible and comparable across runs.
pub fn build_plans(manifest: &Manifest, spec: &LoadSpec) -> Result<Vec<UserPlan>> {
    let sc = &manifest.scenario;
    let vocab = manifest.model.vocab;
    let assign = spec.mix.assign(spec.users);
    let mut datasets: BTreeMap<Workload, Box<dyn OnlineDataset>> = BTreeMap::new();
    for t in &assign {
        let wl = t.workload;
        if wl != Workload::Stream && !datasets.contains_key(&wl) {
            datasets.insert(wl, datagen::by_name(wl.name(), spec.seed, sc, vocab)?);
        }
    }
    // Mean per-user gap that lands the aggregate near `rate` while the
    // whole population is active.
    let mean_gap = if spec.rate > 0.0 { spec.users as f64 / spec.rate as f64 } else { 0.0 };
    let mut plans = Vec::with_capacity(assign.len());
    for (u, &tenant) in assign.iter().enumerate() {
        let wl = tenant.workload;
        let mut rng = Rng::with_stream(spec.seed, u as u64);
        let mut at = Duration::from_secs_f64(rng.f64() * spec.ramp_secs.max(0.0));
        let mut events: Vec<Event> = Vec::new();
        let push = |events: &mut Vec<Event>, at: &mut Duration, rng: &mut Rng, kind| {
            events.push(Event { at: *at, kind, churn_after: rng.bool(spec.churn) });
            let gap = if mean_gap > 0.0 { -mean_gap * (1.0 - rng.f64()).ln() } else { 0.0 };
            *at += Duration::from_secs_f64(gap);
        };
        let quality_user = spec.quality_every > 0 && u % spec.quality_every == 0;
        let mut quality = None;
        match wl {
            Workload::Dialog | Workload::Lamp | Workload::MetaIcl => {
                let ds = datasets.get(&wl).context("dataset built above")?;
                let t_max = ds.t_max().min(sc.t_max).max(1);
                let len = heavy_tail_len(&mut rng, 2, t_max, 1.5);
                let identity = u % ds.n_identities(Split::Test).max(1);
                let full = ds.sample(Split::Test, identity, len);
                for t in 1..=len {
                    let chunk = full.chunks[t - 1].clone();
                    push(&mut events, &mut at, &mut rng, EventKind::Context { tokens: chunk });
                    let step = ds.sample(Split::Test, identity, t);
                    push(&mut events, &mut at, &mut rng, EventKind::Query { tokens: step.input });
                }
                if quality_user && !full.target.is_empty() {
                    quality = Some(QualityProbe { input: full.input, target: full.target });
                }
            }
            Workload::Stream => {
                let mut gen = StreamGen::for_user(spec.seed, u as u64, vocab);
                let len = heavy_tail_len(&mut rng, 2, spec.stream_len_max.max(2), 1.5);
                let chunk_len = sc.chunk_max.clamp(4, 48);
                let qi = (sc.input_max / 2).clamp(1, 8);
                for t in 1..=len {
                    let chunk = gen.take(chunk_len);
                    push(&mut events, &mut at, &mut rng, EventKind::Context { tokens: chunk });
                    if t % 4 == 0 || t == len {
                        let q = gen.take(qi);
                        push(&mut events, &mut at, &mut rng, EventKind::Query { tokens: q });
                    }
                }
                if quality_user {
                    quality = Some(QualityProbe { input: gen.take(qi), target: gen.take(qi) });
                }
            }
        }
        plans.push(UserPlan {
            user: u,
            tenant,
            session: format!("{}-u{u}", wl.name()),
            events,
            quality,
        });
    }
    Ok(plans)
}

// ---------------------------------------------------------------------
// Outcome classification and the refusal-separated recorder.

/// Final outcome of one scheduled request.
#[derive(Debug, Clone, PartialEq)]
pub enum Outcome {
    /// Served: contributes a latency sample.
    Ok,
    /// The server answered with a refusal (`error` code inside).
    /// Never contributes a latency sample.
    Refused(String),
    /// No reply at all after retries (connection died) — must be zero
    /// in a healthy run.
    Lost,
}

/// Classify a protocol reply: `{"ok":true,...}` is served, anything
/// else is a refusal keyed by its `error` code.
pub fn classify(resp: &Json) -> Outcome {
    if resp.opt("ok") == Some(&Json::Bool(true)) {
        return Outcome::Ok;
    }
    let kind =
        resp.opt("error").and_then(|e| e.str().ok()).unwrap_or("malformed_reply").to_string();
    Outcome::Refused(kind)
}

/// Per-scenario accounting. The load-bearing invariant: `lat_us` only
/// ever holds served requests — refusals and losses are counted in
/// their own buckets so overload can never flatter the latency
/// percentiles (covered by `refusals_never_become_latency_samples`).
#[derive(Debug, Clone, Default)]
pub struct Bucket {
    /// Scheduled requests attempted (== ok + refused + lost).
    pub sent: u64,
    pub ok: u64,
    /// Requests whose FINAL outcome was a refusal.
    pub refused: u64,
    /// Requests that got no reply at all (after retries).
    pub lost: u64,
    /// Deliberate churn reconnects (not failures).
    pub reconnects: u64,
    /// Every refusal reply observed, by `error` code — includes
    /// transient `too_many_connections` lines that a retry then
    /// converted into a served request, so this can exceed `refused`.
    pub refusal_kinds: BTreeMap<String, u64>,
    /// Latency samples (µs), measured from the SCHEDULED send time —
    /// served requests only.
    pub lat_us: Vec<u64>,
}

impl Bucket {
    /// Note a refusal reply without deciding the request's outcome
    /// (transient, retried refusals).
    pub fn note_refusal(&mut self, kind: &str) {
        *self.refusal_kinds.entry(kind.to_string()).or_insert(0) += 1;
    }

    /// Record the final outcome of one scheduled request. `lat_us` is
    /// schedule-to-reply and is kept ONLY for served requests.
    pub fn record(&mut self, outcome: &Outcome, lat_us: u64) {
        self.sent += 1;
        match outcome {
            Outcome::Ok => {
                self.ok += 1;
                self.lat_us.push(lat_us);
            }
            Outcome::Refused(kind) => {
                self.refused += 1;
                self.note_refusal(kind);
            }
            Outcome::Lost => self.lost += 1,
        }
    }

    pub fn merge(&mut self, other: &Bucket) {
        self.sent += other.sent;
        self.ok += other.ok;
        self.refused += other.refused;
        self.lost += other.lost;
        self.reconnects += other.reconnects;
        for (k, v) in &other.refusal_kinds {
            *self.refusal_kinds.entry(k.clone()).or_insert(0) += v;
        }
        self.lat_us.extend_from_slice(&other.lat_us);
    }

    /// Latency percentile in ms at per-mille rank (500 = p50, 990 =
    /// p99, 999 = p99.9); 0.0 when no request was served.
    pub fn p_ms(&self, q_mille: usize) -> f64 {
        percentile_mille(&self.lat_us, q_mille).unwrap_or(0) as f64 / 1e3
    }
}

// ---------------------------------------------------------------------
// The user hot loop.

/// Shared per-run context for user threads.
#[derive(Clone)]
struct RunCtx {
    addr: String,
    t0: Instant,
    model: ModelConfig,
    comp_len: usize,
    input_max: usize,
    topk: usize,
}

struct UserConn {
    addr: String,
    client: Option<Client>,
}

impl UserConn {
    fn get(&mut self) -> Result<&mut Client> {
        if self.client.is_none() {
            let mut last: Option<anyhow::Error> = None;
            for _ in 0..CONNECT_ATTEMPTS {
                match Client::connect(&self.addr) {
                    Ok(c) => return Ok(self.client.get_or_insert(c)),
                    Err(e) => {
                        last = Some(e);
                        std::thread::sleep(RETRY_BACKOFF);
                    }
                }
            }
            match last {
                Some(e) => return Err(e),
                None => bail!("connect {} failed", self.addr),
            }
        }
        self.client.as_mut().context("connection present")
    }

    fn drop_conn(&mut self) {
        self.client = None;
    }
}

fn context_req(session: &str, tokens: &[i32], strategy: Option<StrategyKind>) -> String {
    let strategy = match strategy {
        Some(k) => format!(",\"strategy\":\"{}\"", k.name()),
        None => String::new(),
    };
    format!(
        "{{\"op\":\"context\",\"session\":{},\"tokens\":{}{strategy}}}",
        escape(session),
        fmt_tokens(tokens)
    )
}

fn query_req(session: &str, tokens: &[i32], topk: usize) -> String {
    format!(
        "{{\"op\":\"query\",\"session\":{},\"tokens\":{},\"topk\":{topk}}}",
        escape(session),
        fmt_tokens(tokens)
    )
}

/// Send one scheduled request with the connection-level retry budget.
/// `too_many_connections` means the ACCEPT was refused (the request
/// never reached a handler), so it reconnects and retries — noting the
/// refusal reply — while admission refusals are final: an open-loop
/// generator takes the server's no for an answer instead of piling
/// retries onto an overloaded shard.
fn exec_event(conn: &mut UserConn, req: &str, bucket: &mut Bucket) -> (Outcome, Option<Json>) {
    for attempt in 0..EVENT_ATTEMPTS {
        let client = match conn.get() {
            Ok(c) => c,
            Err(_) => continue,
        };
        match client.call(req) {
            Ok(resp) => match classify(&resp) {
                Outcome::Ok => return (Outcome::Ok, Some(resp)),
                Outcome::Refused(kind) => {
                    if kind == "too_many_connections" {
                        conn.drop_conn();
                        if attempt + 1 < EVENT_ATTEMPTS {
                            bucket.note_refusal(&kind);
                            std::thread::sleep(RETRY_BACKOFF);
                            continue;
                        }
                    }
                    return (Outcome::Refused(kind), None);
                }
                Outcome::Lost => return (Outcome::Lost, None),
            },
            Err(_) => {
                // EOF / reset mid-exchange: the reply is gone for good
                // (replies are not idempotent to re-request for
                // context ops — but a context chunk that was never
                // acked was never admitted, so resending is safe).
                conn.drop_conn();
                std::thread::sleep(RETRY_BACKOFF);
            }
        }
    }
    (Outcome::Lost, None)
}

/// One sampled user's scored probe.
#[derive(Debug, Clone)]
pub struct QualitySample {
    /// ROUGE-L F1 of the greedy compressed-memory continuation vs the
    /// full-context reference continuation.
    pub rouge_l: f64,
    /// Analytic full-context peak KV (memacct, `Method::Full`).
    pub kv_full_bytes: u64,
    /// Analytic CCM-concat peak KV at the same shape.
    pub kv_ccm_bytes: u64,
    /// Live compressed-KV bytes from the session's last context ack.
    pub kv_live_bytes: u64,
    pub gen_len: usize,
    pub probes: u64,
    pub probes_refused: u64,
}

/// Aggregate quality view over all sampled users.
#[derive(Debug, Clone, Default)]
pub struct QualityStats {
    pub samples: usize,
    pub rouge_mean: f64,
    pub kv_full_mean: f64,
    pub kv_ccm_mean: f64,
    pub kv_live_mean: f64,
    /// Mean full/ccm peak-KV ratio — the paper's memory-saving factor
    /// at the replayed session shapes.
    pub kv_ratio_mean: f64,
    pub gen_tokens: usize,
    pub probes: u64,
    pub probes_refused: u64,
}

impl QualityStats {
    fn from_samples(samples: &[QualitySample]) -> QualityStats {
        if samples.is_empty() {
            return QualityStats::default();
        }
        let mut out = QualityStats { samples: samples.len(), ..QualityStats::default() };
        for s in samples {
            out.rouge_mean += s.rouge_l;
            out.kv_full_mean += s.kv_full_bytes as f64;
            out.kv_ccm_mean += s.kv_ccm_bytes as f64;
            out.kv_live_mean += s.kv_live_bytes as f64;
            out.kv_ratio_mean += s.kv_full_bytes as f64 / s.kv_ccm_bytes.max(1) as f64;
            out.gen_tokens += s.gen_len;
            out.probes += s.probes;
            out.probes_refused += s.probes_refused;
        }
        let n = samples.len() as f64;
        out.rouge_mean /= n;
        out.kv_full_mean /= n;
        out.kv_ccm_mean /= n;
        out.kv_live_mean /= n;
        out.kv_ratio_mean /= n;
        out
    }
}

fn top1_token(resp: &Json) -> Option<i32> {
    let next = resp.opt("next")?.arr().ok()?;
    let pair = next.first()?.arr().ok()?;
    Some(pair.first()?.i64().ok()? as i32)
}

/// Greedy continuation over the session's compressed memory, scored
/// against the full-context reference. Probe round trips are unpaced
/// bookkeeping, not scheduled load — they never touch the latency
/// pool.
fn score_quality(
    conn: &mut UserConn,
    ctx: &RunCtx,
    session: &str,
    probe: &QualityProbe,
    chunk_lens: &[usize],
    kv_live: u64,
) -> Option<QualitySample> {
    if probe.target.is_empty() || probe.input.is_empty() || chunk_lens.is_empty() {
        return None;
    }
    let budget =
        GEN_BUDGET.min(probe.target.len()).min(ctx.input_max.saturating_sub(probe.input.len()));
    let mut toks = probe.input.clone();
    let mut generated = Vec::new();
    let mut probes = 0u64;
    let mut probes_refused = 0u64;
    for _ in 0..budget {
        if toks.len() >= ctx.input_max {
            break;
        }
        probes += 1;
        let Ok(client) = conn.get() else { break };
        let req = query_req(session, &toks, 1);
        let Ok(resp) = client.call(&req) else { break };
        match classify(&resp) {
            Outcome::Ok => match top1_token(&resp) {
                Some(tok) => {
                    generated.push(tok);
                    toks.push(tok);
                }
                None => break,
            },
            _ => {
                probes_refused += 1;
                break;
            }
        }
    }
    let rouge_l =
        if generated.is_empty() { 0.0 } else { rouge::rouge_l(&generated, &probe.target) };
    let li = probe.input.len();
    let kv_full =
        memacct::peak_kv_bytes(&ctx.model, Method::Full, chunk_lens, li, ctx.comp_len) as u64;
    let kv_ccm =
        memacct::peak_kv_bytes(&ctx.model, Method::CcmConcat, chunk_lens, li, ctx.comp_len) as u64;
    Some(QualitySample {
        rouge_l,
        kv_full_bytes: kv_full,
        kv_ccm_bytes: kv_ccm,
        kv_live_bytes: kv_live,
        gen_len: generated.len(),
        probes,
        probes_refused,
    })
}

struct UserResult {
    tenant: Tenant,
    bucket: Bucket,
    quality: Option<QualitySample>,
}

fn run_user(ctx: &RunCtx, plan: UserPlan) -> UserResult {
    let mut conn = UserConn { addr: ctx.addr.clone(), client: None };
    let mut bucket = Bucket::default();
    let mut chunk_lens: Vec<usize> = Vec::new();
    let mut kv_live = 0u64;
    for ev in &plan.events {
        let sched = ctx.t0 + ev.at;
        let now = Instant::now();
        if sched > now {
            std::thread::sleep(sched - now);
        }
        let req = match &ev.kind {
            EventKind::Context { tokens } => {
                context_req(&plan.session, tokens, plan.tenant.strategy)
            }
            EventKind::Query { tokens } => query_req(&plan.session, tokens, ctx.topk),
        };
        let (outcome, resp) = exec_event(&mut conn, &req, &mut bucket);
        let lat_us = Instant::now().saturating_duration_since(sched).as_micros() as u64;
        bucket.record(&outcome, lat_us);
        if let (EventKind::Context { tokens }, Some(resp)) = (&ev.kind, resp.as_ref()) {
            chunk_lens.push(tokens.len());
            if let Some(kv) = resp.opt("kv_bytes").and_then(|v| v.usize().ok()) {
                kv_live = kv as u64;
            }
        }
        if ev.churn_after {
            conn.drop_conn();
            bucket.reconnects += 1;
        }
    }
    let quality = match plan.quality.as_ref() {
        Some(probe) => score_quality(&mut conn, ctx, &plan.session, probe, &chunk_lens, kv_live),
        None => None,
    };
    UserResult { tenant: plan.tenant, bucket, quality }
}

// ---------------------------------------------------------------------
// Driving a population and aggregating the run.

/// Per-tenant slice of a run: one (workload, admission-tier)
/// population and its refusal-separated accounting.
#[derive(Debug, Clone)]
pub struct ScenarioSummary {
    pub tenant: Tenant,
    pub users: usize,
    pub bucket: Bucket,
}

/// Everything a loadgen run produced.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub users: usize,
    pub wall_secs: f64,
    pub scenarios: Vec<ScenarioSummary>,
    pub total: Bucket,
    pub quality: QualityStats,
}

/// Replay `spec` against the server at `addr`. `manifest` supplies the
/// scenario shapes the generators synthesize at (chunk/input caps,
/// vocab) and the model geometry for KV accounting — it must match
/// what the server was configured with.
pub fn drive(addr: &str, manifest: &Manifest, spec: &LoadSpec) -> Result<RunSummary> {
    let plans = build_plans(manifest, spec)?;
    let mut user_counts: BTreeMap<Tenant, usize> = BTreeMap::new();
    for plan in &plans {
        *user_counts.entry(plan.tenant).or_insert(0) += 1;
    }
    let ctx = RunCtx {
        addr: addr.to_string(),
        // Epoch slightly ahead of spawn so no user starts already late.
        t0: Instant::now() + Duration::from_millis(50),
        model: manifest.model.clone(),
        comp_len: manifest.scenario.comp_len_max,
        input_max: manifest.scenario.input_max,
        topk: spec.topk,
    };
    let mut handles = Vec::with_capacity(plans.len());
    for plan in plans {
        let ctx = ctx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("loadgen-u{}", plan.user))
            .stack_size(USER_STACK)
            .spawn(move || run_user(&ctx, plan))
            .context("spawn loadgen user thread")?;
        handles.push(handle);
    }
    let mut scenarios: BTreeMap<Tenant, ScenarioSummary> = BTreeMap::new();
    let mut total = Bucket::default();
    let mut samples = Vec::new();
    for handle in handles {
        let Ok(result) = handle.join() else { bail!("loadgen user thread panicked") };
        total.merge(&result.bucket);
        let entry = scenarios.entry(result.tenant).or_insert_with(|| ScenarioSummary {
            tenant: result.tenant,
            users: user_counts.get(&result.tenant).copied().unwrap_or(0),
            bucket: Bucket::default(),
        });
        entry.bucket.merge(&result.bucket);
        if let Some(s) = result.quality {
            samples.push(s);
        }
    }
    let wall_secs = Instant::now().saturating_duration_since(ctx.t0).as_secs_f64();
    Ok(RunSummary {
        users: spec.users,
        wall_secs,
        scenarios: scenarios.into_values().collect(),
        total,
        quality: QualityStats::from_samples(&samples),
    })
}

// ---------------------------------------------------------------------
// Report emission (docs/BENCH.md schema) and the `ccm bench` scenario.

fn scenario_row(
    name: &str,
    users: usize,
    bucket: &Bucket,
    wall_secs: f64,
    quality: Option<&QualityStats>,
) -> Scenario {
    let mut sc = Scenario::new(name, None);
    sc.push("users", users as f64);
    sc.push("requests", bucket.sent as f64);
    // Served-per-second, deliberately not sent-per-second: a refusal
    // storm must read as a throughput drop, not a throughput spike.
    sc.push("reqs_per_sec", bucket.ok as f64 / wall_secs.max(1e-9));
    sc.push("p50_ms", bucket.p_ms(500));
    sc.push("p99_ms", bucket.p_ms(990));
    sc.push("p999_ms", bucket.p_ms(999));
    sc.push("refused", bucket.refused as f64);
    sc.push("lost", bucket.lost as f64);
    sc.push("reconnects", bucket.reconnects as f64);
    if let Some(q) = quality {
        sc.push("quality_samples", q.samples as f64);
        sc.push("rouge_mean", q.rouge_mean);
        sc.push("kv_full_kb_mean", q.kv_full_mean / 1024.0);
        sc.push("kv_live_kb_mean", q.kv_live_mean / 1024.0);
        sc.push("kv_ratio_mean", q.kv_ratio_mean);
    }
    sc
}

/// The aggregate scenario row: `loadgen-mixed` for a mixed population,
/// `loadgen-<tenant>` for a single-population run (`loadgen-dialog`,
/// or `loadgen-dialog@ccm` when the slice pins a tier).
pub fn aggregate_scenario(summary: &RunSummary) -> Scenario {
    let name = match summary.scenarios.as_slice() {
        [only] => format!("loadgen-{}", only.tenant.name()),
        _ => "loadgen-mixed".to_string(),
    };
    scenario_row(&name, summary.users, &summary.total, summary.wall_secs, Some(&summary.quality))
}

/// Full Report for `--emit`: one row per tenant (when mixed) plus
/// the aggregate row carrying the quality metrics.
pub fn to_report(summary: &RunSummary) -> Report {
    let mut report = Report::new(10);
    if summary.scenarios.len() > 1 {
        for s in &summary.scenarios {
            report.scenarios.push(scenario_row(
                &format!("loadgen-{}", s.tenant.name()),
                s.users,
                &s.bucket,
                summary.wall_secs,
                None,
            ));
        }
    }
    report.scenarios.push(aggregate_scenario(summary));
    report
}

fn print_summary(summary: &RunSummary) {
    let row = |name: &str, users: usize, b: &Bucket| -> Vec<String> {
        vec![
            name.to_string(),
            users.to_string(),
            b.sent.to_string(),
            b.ok.to_string(),
            b.refused.to_string(),
            b.lost.to_string(),
            b.reconnects.to_string(),
            format!("{:.3}", b.p_ms(500)),
            format!("{:.3}", b.p_ms(990)),
            format!("{:.3}", b.p_ms(999)),
        ]
    };
    let mut rows: Vec<Vec<String>> = summary
        .scenarios
        .iter()
        .map(|s| row(&s.tenant.name(), s.users, &s.bucket))
        .collect();
    if summary.scenarios.len() > 1 {
        rows.push(row("total", summary.users, &summary.total));
    }
    print_table(
        "loadgen",
        &[
            "scenario", "users", "sent", "ok", "refused", "lost", "reconn", "p50 ms", "p99 ms",
            "p99.9 ms",
        ],
        &rows,
    );
    if !summary.total.refusal_kinds.is_empty() {
        let kinds: Vec<String> = summary
            .total
            .refusal_kinds
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        println!("refusal replies: {}", kinds.join(" "));
    }
    let q = &summary.quality;
    if q.samples > 0 {
        println!(
            "quality: {} sampled sessions, rouge-l {:.3}, peak-KV full {:.1} KiB vs ccm {:.1} \
             KiB ({:.2}x), live {:.1} KiB, {} gen tokens ({} probes, {} refused)",
            q.samples,
            q.rouge_mean,
            q.kv_full_mean / 1024.0,
            q.kv_ccm_mean / 1024.0,
            q.kv_ratio_mean,
            q.kv_live_mean / 1024.0,
            q.gen_tokens,
            q.probes,
            q.probes_refused,
        );
    }
    println!(
        "wall {:.2}s, {:.0} req/s offered, {} served / {} refused / {} lost",
        summary.wall_secs,
        summary.total.sent as f64 / summary.wall_secs.max(1e-9),
        summary.total.ok,
        summary.total.refused,
        summary.total.lost,
    );
}

/// Spin up the self-serve SimCompute server `ccm loadgen` drives when
/// no `--addr` is given: `shards` in-process shard executors behind
/// the standard front-end at the bench-manifest shapes, `delay_us`
/// simulated compute per batch. `default_strategy` pins the server's
/// default admission tier (the `ccm serve --strategy` knob), so a
/// replay can run wholesale under a non-default strategy. `hibernate`
/// enables tiered session memory: idle sessions spill their `Mem(t)`
/// snapshots under the given root after the given threshold (the `ccm
/// serve --hibernate-dir/--hibernate-after-secs` knobs).
fn self_serve(
    shards: usize,
    delay_us: u64,
    default_strategy: Option<StrategyKind>,
    hibernate: Option<(std::path::PathBuf, Duration)>,
) -> Result<(String, std::thread::JoinHandle<Result<()>>)> {
    let mut cfg = super::serving::bench_cfg();
    if let Some(kind) = default_strategy {
        cfg.default_strategy = kind;
    }
    if let Some((dir, after)) = hibernate {
        cfg.hibernate_dir = Some(dir);
        cfg.hibernate_after = Some(after);
    }
    let (ready_tx, ready_rx) = channel();
    let handle = std::thread::spawn(move || {
        let manifest = super::serving::bench_manifest();
        let factories: Vec<BackendFactory<'static>> = (0..shards)
            .map(|_| {
                let sim = super::serving::bench_sim(&manifest, delay_us);
                let factory: BackendFactory<'static> =
                    Box::new(move || Ok(Box::new(sim) as Box<dyn Compute>));
                factory
            })
            .collect();
        serve_sharded(&manifest, factories, cfg, Some(ready_tx))
    });
    let addr = ready_rx.recv().context("loadgen self-serve server ready")?;
    Ok((addr, handle))
}

/// The pinned `loadgen-mixed` trajectory scenario for `ccm bench`
/// (docs/BENCH.md): a mixed population against a self-served 2-shard
/// SimCompute server.
pub fn bench_scenario(users: usize, seed: u64) -> Result<Scenario> {
    let spec = LoadSpec {
        users,
        mix: Mix::mixed(),
        rate: 600.0,
        seed,
        churn: 0.05,
        quality_every: 8,
        ramp_secs: 0.25,
        stream_len_max: 8,
        topk: 3,
    };
    let manifest = super::serving::bench_manifest();
    let (addr, server) = self_serve(2, 100, None, None)?;
    let summary = drive(&addr, &manifest, &spec)?;
    let mut admin = Client::connect(&addr)?;
    admin.shutdown()?;
    // lint: allow(unwrap) — a panicked server thread is a bench bug;
    // re-raise it.
    server.join().expect("loadgen bench server thread")?;
    if summary.total.lost > 0 {
        bail!("loadgen lost {} replies; the numbers would be meaningless", summary.total.lost);
    }
    Ok(aggregate_scenario(&summary))
}

/// The pinned two-tier A/B trajectory scenarios for `ccm bench`
/// (docs/BENCH.md): one dialog population split 3:1 across the `ccm`
/// and `none` admission tiers against the same self-served server,
/// emitting one row per tier (`loadgen-dialog@ccm`,
/// `loadgen-dialog@none`) so the trajectory records per-tier latency
/// and refusal counts side by side.
pub fn bench_tier_scenarios(users: usize, seed: u64) -> Result<Vec<Scenario>> {
    let spec = LoadSpec {
        users,
        mix: Mix::parse("dialog@ccm=3,dialog@none=1")?,
        rate: 600.0,
        seed,
        churn: 0.0,
        quality_every: 0,
        ramp_secs: 0.25,
        stream_len_max: 8,
        topk: 3,
    };
    let manifest = super::serving::bench_manifest();
    let (addr, server) = self_serve(2, 100, None, None)?;
    let summary = drive(&addr, &manifest, &spec)?;
    let mut admin = Client::connect(&addr)?;
    admin.shutdown()?;
    // lint: allow(unwrap) — a panicked server thread is a bench bug;
    // re-raise it.
    server.join().expect("loadgen tier bench server thread")?;
    if summary.total.lost > 0 {
        bail!(
            "tiered loadgen lost {} replies; the numbers would be meaningless",
            summary.total.lost
        );
    }
    Ok(summary
        .scenarios
        .iter()
        .map(|s| {
            scenario_row(
                &format!("loadgen-{}", s.tenant.name()),
                s.users,
                &s.bucket,
                summary.wall_secs,
                None,
            )
        })
        .collect())
}

/// The pinned `loadgen-idle-spill` trajectory scenario for `ccm bench`
/// (docs/BENCH.md): an idle-heavy dialog population whose per-user
/// think time dwarfs the server's hibernate threshold, so sessions
/// spill their `Mem(t)` to disk between turns and rehydrate
/// transparently on the next touch. The row carries the
/// spill/rehydration counters next to the open-loop latency
/// percentiles, so the trajectory tracks what hibernation costs on the
/// serving path.
pub fn bench_idle_spill_scenario(users: usize, seed: u64) -> Result<Scenario> {
    let spec = LoadSpec {
        users,
        mix: Mix::single(Workload::Dialog),
        // Mean per-user think time of ~400 ms against the 100 ms spill
        // threshold below: most inter-turn gaps hibernate the session.
        rate: users as f32 / 0.4,
        seed,
        churn: 0.0,
        quality_every: 0,
        ramp_secs: 0.25,
        stream_len_max: 8,
        topk: 3,
    };
    let root = std::env::temp_dir().join(format!("ccm-bench-idle-spill-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let manifest = super::serving::bench_manifest();
    let (addr, server) =
        self_serve(2, 100, None, Some((root.clone(), Duration::from_millis(100))))?;
    let summary = drive(&addr, &manifest, &spec)?;
    let mut admin = Client::connect(&addr)?;
    let stats = admin.stats()?;
    let spills = stats.get("spills")?.usize()?;
    let rehydrations = stats.get("rehydrations")?.usize()?;
    let corrupt = stats.get("snapshot_corrupt")?.usize()?;
    admin.shutdown()?;
    // lint: allow(unwrap) — a panicked server thread is a bench bug;
    // re-raise it.
    server.join().expect("idle-spill bench server thread")?;
    let _ = std::fs::remove_dir_all(&root);
    if summary.total.lost > 0 {
        bail!(
            "idle-spill loadgen lost {} replies; the numbers would be meaningless",
            summary.total.lost
        );
    }
    if spills == 0 {
        bail!("idle-spill bench never hibernated a session; the row would be meaningless");
    }
    if corrupt > 0 {
        bail!("{corrupt} snapshots decoded corrupt under healthy spill/rehydrate traffic");
    }
    let mut sc =
        scenario_row("loadgen-idle-spill", summary.users, &summary.total, summary.wall_secs, None);
    sc.push("spills", spills as f64);
    sc.push("rehydrations", rehydrations as f64);
    Ok(sc)
}

/// `ccm loadgen` entry point (dispatched from `cli_loadgen`). Without
/// `--addr` it self-serves a `--shards`-way SimCompute server so the
/// whole replay is one command; with `--addr` it drives an external
/// `ccm serve` instance over the same client protocol.
pub fn run(args: &Args) -> Result<()> {
    let spec = LoadSpec::from_args(args)?;
    let manifest = super::serving::bench_manifest();
    let (summary, server) = match args.flags.get("addr") {
        Some(addr) => (drive(addr, &manifest, &spec)?, None),
        None => {
            let shards = args.usize("shards", 2)?.max(1);
            let delay_us = args.u64("sim-delay-us", 100)?;
            let strategy = match args.flags.get("strategy") {
                Some(s) => Some(StrategyKind::parse(s)?),
                None => None,
            };
            let hibernate = match args.flags.get("hibernate-dir") {
                Some(dir) if !dir.is_empty() => Some((
                    std::path::PathBuf::from(dir),
                    Duration::from_millis(args.u64("hibernate-after-ms", 200)?),
                )),
                _ => None,
            };
            let (addr, handle) = self_serve(shards, delay_us, strategy, hibernate)?;
            let summary = drive(&addr, &manifest, &spec)?;
            let mut admin = Client::connect(&addr)?;
            admin.shutdown()?;
            (summary, Some(handle))
        }
    };
    if let Some(handle) = server {
        // lint: allow(unwrap) — a panicked self-serve server thread is
        // a loadgen bug; re-raise it.
        handle.join().expect("loadgen self-serve server thread")?;
    }
    print_summary(&summary);
    if let Some(path) = args.flags.get("emit") {
        let report = to_report(&summary);
        std::fs::write(path, report.to_json()).with_context(|| format!("write {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refusals_never_become_latency_samples() {
        let mut b = Bucket::default();
        b.record(&Outcome::Ok, 1200);
        b.record(&Outcome::Refused("too_many_connections".into()), 9999);
        b.record(&Outcome::Refused("overloaded".into()), 8888);
        b.record(&Outcome::Lost, 7777);
        assert_eq!(b.sent, 4);
        assert_eq!(b.ok, 1);
        assert_eq!(b.refused, 2);
        assert_eq!(b.lost, 1);
        assert_eq!(b.lat_us, vec![1200], "only the served request may contribute latency");
        assert_eq!(b.refusal_kinds.get("too_many_connections"), Some(&1));
        assert_eq!(b.refusal_kinds.get("overloaded"), Some(&1));
        // A transient refusal that a retry later converts to Ok still
        // shows up in the kind breakdown but not as a refused event.
        let mut b = Bucket::default();
        b.note_refusal("too_many_connections");
        b.record(&Outcome::Ok, 450);
        assert_eq!((b.sent, b.ok, b.refused), (1, 1, 0));
        assert_eq!(b.refusal_kinds.get("too_many_connections"), Some(&1));
        assert_eq!(b.lat_us, vec![450]);
    }

    #[test]
    fn classify_separates_served_from_refusals() {
        let ok = Json::parse(r#"{"ok":true,"kind":"context","t":1,"kv_bytes":0}"#).unwrap();
        assert_eq!(classify(&ok), Outcome::Ok);
        let conns = Json::parse(r#"{"ok":false,"error":"too_many_connections"}"#).unwrap();
        assert_eq!(classify(&conns), Outcome::Refused("too_many_connections".into()));
        let over = Json::parse(r#"{"ok":false,"error":"overloaded","pending":4}"#).unwrap();
        assert_eq!(classify(&over), Outcome::Refused("overloaded".into()));
        let junk = Json::parse(r#"{"ok":false}"#).unwrap();
        assert_eq!(classify(&junk), Outcome::Refused("malformed_reply".into()));
    }

    #[test]
    fn mix_apportionment_is_exact_and_covers_all_workloads() {
        let assign = Mix::mixed().assign(200);
        assert_eq!(assign.len(), 200);
        for wl in Workload::ALL {
            assert!(
                assign.iter().any(|t| t.workload == wl),
                "{} missing from mixed/200",
                wl.name()
            );
        }
        assert!(assign.iter().all(|t| t.strategy.is_none()), "mixed default is untiered");
        assert_eq!(Mix::mixed().assign(0).len(), 0);
        assert_eq!(
            Mix::single(Workload::Dialog).assign(5),
            vec![Tenant::untiered(Workload::Dialog); 5]
        );
        let two = Mix::parse("dialog=1,metaicl=1").unwrap().assign(24);
        assert_eq!(two.iter().filter(|t| t.workload == Workload::Dialog).count(), 12);
        assert_eq!(two.iter().filter(|t| t.workload == Workload::MetaIcl).count(), 12);
        assert!(Mix::parse("dialog=0").is_err());
        assert!(Mix::parse("nope=1").is_err());
    }

    #[test]
    fn tier_mix_parses_and_threads_the_strategy_field() {
        // `workload@tier=weight` splits one workload across admission
        // tiers; apportionment stays exact per (workload, tier) slice.
        let mix = Mix::parse("dialog@ccm=3,dialog@none=1").unwrap();
        let assign = mix.assign(8);
        assert_eq!(assign.iter().filter(|t| t.strategy == Some(StrategyKind::Ccm)).count(), 6);
        assert_eq!(
            assign.iter().filter(|t| t.strategy == Some(StrategyKind::NoCompress)).count(),
            2
        );
        assert_eq!(Tenant::untiered(Workload::Dialog).name(), "dialog");
        assert_eq!(assign[0].name(), "dialog@ccm");
        assert!(Mix::parse("dialog@nope=1").is_err(), "unknown tier must be rejected");
        // The pinned tier reaches the wire as the `op:"context"`
        // strategy field; untiered sessions omit it entirely so they
        // ride the server's default-tier admission.
        let req = context_req("s", &[1, 2], Some(StrategyKind::SlidingWindow));
        let j = Json::parse(&req).unwrap();
        assert_eq!(j.get("strategy").unwrap().str().unwrap(), "sliding-window");
        let req = context_req("s", &[1, 2], None);
        assert!(Json::parse(&req).unwrap().opt("strategy").is_none());
    }

    #[test]
    fn heavy_tail_lengths_stay_in_bounds_and_skew_short() {
        let mut rng = Rng::new(3);
        let mut lens = Vec::new();
        for _ in 0..500 {
            lens.push(heavy_tail_len(&mut rng, 2, 16, 1.5));
        }
        assert!(lens.iter().all(|&l| (2..=16).contains(&l)));
        let short = lens.iter().filter(|&&l| l <= 4).count();
        assert!(short > 250, "heavy tail must skew short ({short}/500 <= 4)");
        assert!(lens.iter().any(|&l| l >= 8), "the tail must reach deep sessions");
        assert_eq!(heavy_tail_len(&mut rng, 2, 2, 1.5), 2);
    }

    #[test]
    fn plans_are_deterministic_and_monotonically_scheduled() {
        let manifest = crate::model::Manifest::toy();
        let spec = LoadSpec {
            users: 12,
            mix: Mix::mixed(),
            rate: 100.0,
            seed: 11,
            churn: 0.2,
            quality_every: 4,
            ramp_secs: 0.2,
            stream_len_max: 6,
            topk: 3,
        };
        let a = build_plans(&manifest, &spec).unwrap();
        let b = build_plans(&manifest, &spec).unwrap();
        assert_eq!(a, b, "plans must be a pure function of (seed, spec)");
        assert_eq!(a.len(), 12);
        for plan in &a {
            assert!(!plan.events.is_empty());
            for w in plan.events.windows(2) {
                assert!(w[0].at <= w[1].at, "per-user schedule must be monotone");
            }
            assert!(plan.session.starts_with(plan.tenant.workload.name()));
        }
        // Sampled users carry a probe (the dialog/stream targets are
        // always non-empty).
        assert!(a.iter().any(|p| p.quality.is_some()));
        assert!(a.iter().filter(|p| p.user % 4 != 0).all(|p| p.quality.is_none()));
    }

    #[test]
    fn report_rows_compose_with_the_bench_schema() {
        let mut bucket = Bucket::default();
        bucket.record(&Outcome::Ok, 900);
        bucket.record(&Outcome::Ok, 1100);
        bucket.record(&Outcome::Refused("overloaded".into()), 5000);
        let summary = RunSummary {
            users: 2,
            wall_secs: 1.0,
            scenarios: vec![
                ScenarioSummary {
                    tenant: Tenant::untiered(Workload::Dialog),
                    users: 1,
                    bucket: bucket.clone(),
                },
                ScenarioSummary {
                    tenant: Tenant {
                        workload: Workload::Dialog,
                        strategy: Some(StrategyKind::NoCompress),
                    },
                    users: 1,
                    bucket: bucket.clone(),
                },
            ],
            total: bucket,
            quality: QualityStats { samples: 1, rouge_mean: 0.5, ..QualityStats::default() },
        };
        let report = to_report(&summary);
        let parsed = Report::parse(&report.to_json()).expect("schema-valid report");
        assert_eq!(parsed.pr, 10);
        let agg = parsed.find("loadgen-mixed", None).expect("aggregate row");
        assert_eq!(agg.metric("refused"), Some(1.0));
        assert_eq!(agg.metric("quality_samples"), Some(1.0));
        assert!(agg.metric("p99_ms").is_some());
        let dialog = parsed.find("loadgen-dialog", None).expect("per-scenario row");
        assert!(dialog.metric("p50_ms").is_some());
        // A tiered slice reports under its `workload@tier` name so the
        // trajectory keeps the tiers' tails side by side.
        assert!(parsed.find("loadgen-dialog@none", None).is_some());
    }
}
