//! Experiment drivers: one entry per paper table/figure (DESIGN.md §6).
//!
//! `ccm reproduce --exp <id>` regenerates the table/figure on the
//! synthetic suites. Checkpoints are trained on demand and cached under
//! `runs/<config>/`, so drivers compose: fig6 reuses fig7's adapters etc.
//! Every driver prints the table and appends it to `results/<exp>.md`.

pub mod experiments;
pub mod loadgen;
pub mod serving;

/// All experiments share one base LM pretrained on the full mixture —
/// the paper's Table-4/15 observation that adapter *training data* (not
/// the base) is what varies across settings.
pub const UNIFIED: &str = "metaicl+lamp+dialog";

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::datagen::corpus::Mixture;
use crate::masks::{MergeScheme, Method};
use crate::model::{Checkpoint, Manifest};
use crate::runtime::Runtime;
use crate::training::pack::PackPolicy;
use crate::training::Trainer;
use crate::util::cli::Args;

/// Tunables every driver respects (scaled for the CPU testbed; raise for
/// closer-to-paper fidelity).
#[derive(Debug, Clone)]
pub struct Budget {
    pub steps_lm: usize,
    pub steps_adapter: usize,
    pub steps_rmt: usize,
    pub eval_n: usize,
    pub t_values: Vec<usize>,
    pub seed: u64,
}

impl Budget {
    pub fn from_args(args: &Args) -> Result<Budget> {
        Ok(Budget {
            steps_lm: args.usize("steps-lm", 400)?,
            steps_adapter: args.usize("steps", 60)?,
            steps_rmt: args.usize("steps-rmt", 12)?,
            eval_n: args.usize("eval-n", 48)?,
            t_values: args
                .list("t", &["1", "2", "4", "8"])
                .iter()
                .map(|s| s.parse().map_err(|_| anyhow::anyhow!("bad --t value {s}")))
                .collect::<Result<_>>()?,
            seed: args.u64("seed", 7)?,
        })
    }
}

/// Shared context: runtime + checkpoint cache.
pub struct ExpContext {
    pub rt: Runtime,
    pub budget: Budget,
    pub runs_dir: PathBuf,
    cache: HashMap<String, Checkpoint>,
}

/// Adapter descriptor — the cache key components.
#[derive(Debug, Clone)]
pub struct AdapterSpec {
    pub method: Method,
    pub scheme: MergeScheme,
    pub comp_len: usize,
    pub conditional: bool,
    pub mixture: String,
}

impl AdapterSpec {
    pub fn new(method: Method, comp_len: usize, mixture: &str) -> AdapterSpec {
        AdapterSpec {
            method,
            scheme: MergeScheme::Avg,
            comp_len,
            conditional: true,
            mixture: mixture.to_string(),
        }
    }

    pub fn policy(&self) -> PackPolicy {
        PackPolicy {
            method: self.method,
            scheme: self.scheme,
            comp_len: self.comp_len,
            conditional: self.conditional,
        }
    }

    fn key(&self, steps: usize) -> String {
        let scheme = match self.scheme {
            MergeScheme::Avg => "avg".to_string(),
            MergeScheme::Ema(a) => format!("ema{a}"),
        };
        format!(
            "adapter-{}-{}-cl{}-{}-{}-s{}",
            self.method.name(),
            scheme,
            self.comp_len,
            if self.conditional { "cond" } else { "uncond" },
            self.mixture.replace('+', "_"),
            steps
        )
    }
}

impl ExpContext {
    pub fn new(config: &str, budget: Budget) -> Result<ExpContext> {
        let rt = Runtime::from_config(config)?;
        let runs_dir = crate::model::artifact_dir(config)
            .parent()
            .map(|p| p.parent().unwrap_or(p).join("runs").join(config))
            .unwrap_or_else(|| PathBuf::from("runs").join(config));
        std::fs::create_dir_all(&runs_dir)?;
        Ok(ExpContext { rt, budget, runs_dir, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.rt.manifest
    }

    /// Base LM checkpoint for a training mixture (train-if-missing).
    pub fn base(&mut self, mixture: &str) -> Result<Checkpoint> {
        let key = format!("base-{}-s{}", mixture.replace('+', "_"), self.budget.steps_lm);
        if let Some(ck) = self.cache.get(&key) {
            return Ok(ck.clone());
        }
        let path = self.runs_dir.join(format!("{key}.bin"));
        let ck = if path.exists() {
            Checkpoint::load(&path, &self.rt.manifest)?
        } else {
            crate::info!("training base LM [{key}] ({} steps)...", self.budget.steps_lm);
            let mut ck = Checkpoint::init(&self.rt.manifest, self.budget.seed);
            let trainer = Trainer::new(&self.rt);
            let rep = trainer.pretrain_lm(
                &mut ck,
                &Mixture::parse(mixture),
                self.budget.steps_lm,
                3e-3,
                self.budget.seed,
            )?;
            crate::info!("base LM [{key}]: final loss {:.4}", rep.final_loss());
            ck.save(&path)?;
            ck
        };
        self.cache.insert(key, ck.clone());
        Ok(ck)
    }

    /// Compression adapter on top of `base(mixture)` (train-if-missing).
    pub fn adapter(&mut self, spec: &AdapterSpec) -> Result<Checkpoint> {
        let steps = self.budget.steps_adapter;
        let key = spec.key(steps);
        if let Some(ck) = self.cache.get(&key) {
            return Ok(ck.clone());
        }
        let path = self.runs_dir.join(format!("{key}.bin"));
        let ck = if path.exists() {
            Checkpoint::load(&path, &self.rt.manifest)?
        } else {
            let mut ck = self.base(UNIFIED)?;
            crate::info!("training adapter [{key}] ({steps} steps)...");
            let trainer = Trainer::new(&self.rt);
            let rep = trainer.train_ccm(
                &mut ck,
                &spec.policy(),
                &Mixture::parse(&spec.mixture),
                steps,
                1e-2,
                self.budget.seed ^ 0xADA,
            )?;
            crate::info!("adapter [{key}]: final loss {:.4}", rep.final_loss());
            ck.save(&path)?;
            ck
        };
        self.cache.insert(key, ck.clone());
        Ok(ck)
    }

    /// RMT baseline checkpoint (train-if-missing; sequential = slow).
    pub fn rmt(&mut self, mixture: &str) -> Result<(Checkpoint, f64)> {
        let steps = self.budget.steps_rmt;
        let key = format!("rmt-{}-s{steps}", mixture.replace('+', "_"));
        let path = self.runs_dir.join(format!("{key}.bin"));
        let ms_path = self.runs_dir.join(format!("{key}.ms"));
        if path.exists() && ms_path.exists() {
            let ck = Checkpoint::load(&path, &self.rt.manifest)?;
            let ms: f64 = std::fs::read_to_string(&ms_path)?.trim().parse().unwrap_or(0.0);
            return Ok((ck, ms));
        }
        let mut ck = self.base(UNIFIED)?;
        crate::info!("training RMT baseline [{key}] ({steps} steps, sequential)...");
        let trainer = Trainer::new(&self.rt);
        let rep =
            trainer.train_rmt(&mut ck, &Mixture::parse(mixture), steps, 3e-3, self.budget.seed)?;
        ck.save(&path)?;
        std::fs::write(&ms_path, rep.ms_per_sample.to_string())?;
        Ok((ck, rep.ms_per_sample))
    }

    /// Write a result table to `results/<exp>.md` and stdout.
    pub fn emit(
        &self,
        exp: &str,
        title: &str,
        header: &[&str],
        rows: &[Vec<String>],
    ) -> Result<()> {
        crate::util::bench::print_table(title, header, rows);
        let dir =
            self.runs_dir.parent().map(|p| p.parent().unwrap_or(p)).unwrap_or(&self.runs_dir);
        let results = dir.join("results");
        std::fs::create_dir_all(&results)?;
        let mut md = format!("## {title}\n\n|{}|\n|{}|\n", header.join("|"),
            header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for row in rows {
            md.push_str(&format!("|{}|\n", row.join("|")));
        }
        md.push('\n');
        std::fs::write(results.join(format!("{exp}.md")), md)?;
        Ok(())
    }
}

/// Dispatch `reproduce --exp <id>`.
pub fn run(exp: &str, args: &Args) -> Result<()> {
    let config = args.str("config", "main");
    let budget = Budget::from_args(args)?;
    let mut ctx = ExpContext::new(&config, budget)?;
    match exp {
        "fig6" => experiments::fig6_memory_perf(&mut ctx, args),
        "fig7" | "tables23-25" | "table23" | "table24" | "table25" => {
            experiments::fig7_methods(&mut ctx, args)
        }
        "fig8" | "fig9" => experiments::fig8_streaming(&mut ctx, args),
        "fig10" => experiments::fig10_all_datasets(&mut ctx, args),
        "table1" => experiments::table1_throughput(&mut ctx, args),
        "table3" | "table17" => experiments::table3_complexity(&mut ctx, args),
        "table4" => experiments::table4_datasources(&mut ctx, args),
        "table5" | "table21" => experiments::table5_cond_lora(&mut ctx, args),
        "table6" => experiments::table6_fixed_context(&mut ctx, args),
        "table7" => experiments::table7_rougel(&mut ctx, args),
        "table8" | "table22" => experiments::table8_recurrent(&mut ctx, args),
        "table9" => experiments::table9_summarization(&mut ctx, args),
        "table15" => experiments::table15_unified(&mut ctx, args),
        "table16" => experiments::table16_ema(&mut ctx, args),
        "table18" => experiments::table18_comp_len(&mut ctx, args),
        "table19" | "table20" => experiments::table19_scale(&mut ctx, args),
        "all" => {
            for e in [
                "table3", "fig7", "fig6", "fig10", "table5", "table6", "table7", "table9",
                "table15", "table16", "table18", "table4", "table8", "table1", "fig8",
            ] {
                crate::info!("=== reproduce {e} ===");
                run(e, args)?;
            }
            Ok(())
        }
        _ => bail!("unknown experiment {exp:?} (see DESIGN.md §6)"),
    }
}
