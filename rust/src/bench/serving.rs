//! Serving-layer benchmark scenarios behind `ccm bench`: the perf
//! trajectory for the serving stack, runnable anywhere (SimCompute
//! backend, no artifacts). Three scenario families:
//!
//! * `serve-throughput` — the in-process TCP serve path end to end
//!   (reactor front-end, admission, batcher, session memory).
//! * `ipc-2worker` — two shard worker PROCESSES behind the pipelined
//!   IPC hop, run once per `--ipc-codec` value; alongside client-side
//!   round latency it records the per-worker IPC RTT p50/p99 that the
//!   proxy's sliding sample window exposes in merged stats — the
//!   json-vs-binary delta is the codec's cost on the wire.
//! * `stress-profile` — wider concurrent fan-in with a faster backend,
//!   profiling the tail (`round_p99_ms`) rather than throughput.
//! * `loadgen-mixed` — the paper-workload traffic replay
//!   ([`super::loadgen`], docs/SCENARIOS.md): a pinned mixed
//!   multi-tenant population against a 2-shard server, reporting
//!   open-loop latency percentiles, refusal counts, and sampled
//!   compression-quality signals.
//! * `loadgen-dialog@ccm` / `loadgen-dialog@none` — the pinned
//!   two-tier A/B replay ([`super::loadgen::bench_tier_scenarios`]):
//!   one dialog population split 3:1 across the `ccm` and `none`
//!   admission tiers, one row per tier so the trajectory tracks
//!   per-tier latency.
//! * `loadgen-idle-spill` — the pinned idle-heavy replay
//!   ([`super::loadgen::bench_idle_spill_scenario`]) against a
//!   hibernating server: per-user think time dwarfs the spill
//!   threshold, so sessions hibernate to disk between turns and
//!   rehydrate on the next touch; the row records the spill and
//!   rehydration counters next to the open-loop latency.
//!
//! `--emit PATH` writes the machine-readable `BENCH_<n>.json` report
//! ([`Report`]; schema in docs/BENCH.md). `--compare OLD --against
//! NEW` renders a markdown delta table (CI pipes it into the job
//! summary) and exits nonzero when the IPC RTT p99 regressed past
//! [`RTT_P99_BUDGET`] — advisory in CI, because shared runners are
//! noisy, but the delta is always visible.
//!
//! `ccm bench --worker --shard K --shards N --ipc-codec C` is the
//! re-exec entry the IPC scenarios spawn their workers through (the
//! same binary, SimCompute backend, no artifacts needed).

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::compress::{Compute, SimCompute};
use crate::coordinator::session::SessionPolicy;
use crate::model::manifest::ScenarioConfig;
use crate::model::Manifest;
use crate::server::{
    serve_with_backend, serve_workers, BackendFactory, Client, IpcCodec, ServerConfig, WorkerMode,
};
use crate::util::bench::{percentile, print_table, Report, Scenario};
use crate::util::cli::Args;
use crate::util::json::Json;

/// Relative IPC RTT p99 budget for `--compare`: the comparison fails
/// when `new > old * RTT_P99_BUDGET` on any `ipc_rtt_p99_ms` metric.
pub const RTT_P99_BUDGET: f64 = 1.25;

/// Context tokens per round: roomy enough that the per-frame JSON
/// encode/parse cost the binary codec removes is a visible fraction of
/// the IPC round trip, not noise under the 200 µs simulated compute.
const CTX_TOKENS: usize = 64;

/// `ccm bench` entry point (dispatched from `cli_bench`).
pub fn run(args: &Args) -> Result<()> {
    if args.bool("worker") {
        return worker_main(args);
    }
    if let Some(old_path) = args.flags.get("compare") {
        return run_compare(old_path, args.require("against")?);
    }
    let clients = args.usize("clients", 8)?;
    let rounds = args.usize("rounds", 120)?;
    let stress_clients = args.usize("stress-clients", 32)?;
    let stress_rounds = args.usize("stress-rounds", 40)?;
    let loadgen_users = args.usize("loadgen-users", 64)?;
    let mut report = Report::new(10);
    report.scenarios.push(scenario_inprocess("serve-throughput", clients, rounds, 200)?);
    report.scenarios.push(scenario_ipc(IpcCodec::Json, clients, rounds)?);
    report.scenarios.push(scenario_ipc(IpcCodec::Binary, clients, rounds)?);
    let stress = scenario_inprocess("stress-profile", stress_clients, stress_rounds, 50)?;
    report.scenarios.push(stress);
    report.scenarios.push(super::loadgen::bench_scenario(loadgen_users, 7)?);
    report.scenarios.extend(super::loadgen::bench_tier_scenarios(loadgen_users, 7)?);
    report.scenarios.push(super::loadgen::bench_idle_spill_scenario(loadgen_users, 7)?);
    let metric = |sc: &Scenario, name: &str| match sc.metric(name) {
        Some(v) => format!("{v:.3}"),
        None => "-".into(),
    };
    let rows: Vec<Vec<String>> = report
        .scenarios
        .iter()
        .map(|sc| {
            // The loadgen scenario reports open-loop request metrics
            // under its own names (docs/BENCH.md).
            let (rate, p50, p99) = if sc.name.starts_with("loadgen") {
                ("reqs_per_sec", "p50_ms", "p99_ms")
            } else {
                ("rounds_per_sec", "round_p50_ms", "round_p99_ms")
            };
            vec![
                sc.label(),
                metric(sc, rate),
                metric(sc, p50),
                metric(sc, p99),
                metric(sc, "ipc_rtt_p50_ms"),
                metric(sc, "ipc_rtt_p99_ms"),
            ]
        })
        .collect();
    print_table(
        "serving benchmarks",
        &["scenario", "rounds/s", "p50 ms", "p99 ms", "ipc p50 ms", "ipc p99 ms"],
        &rows,
    );
    if let Some(path) = args.flags.get("emit") {
        std::fs::write(path, report.to_json()).with_context(|| format!("write {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn run_compare(old_path: &str, new_path: &str) -> Result<()> {
    let read = |path: &str| -> Result<Report> {
        Report::parse(&std::fs::read_to_string(path).with_context(|| format!("read {path}"))?)
            .with_context(|| format!("parse {path}"))
    };
    let (old, new) = (read(old_path)?, read(new_path)?);
    let (table, regressions) = compare(&old, &new);
    println!("{table}");
    if !regressions.is_empty() {
        bail!(
            "IPC RTT p99 regressed past the {:.0}% budget:\n  {}",
            (RTT_P99_BUDGET - 1.0) * 100.0,
            regressions.join("\n  ")
        );
    }
    Ok(())
}

/// Render the markdown delta table of `new` vs the `old` baseline and
/// collect the budget-violating `ipc_rtt_p99_ms` regressions.
pub fn compare(old: &Report, new: &Report) -> (String, Vec<String>) {
    let mut out = String::from(
        "| scenario | metric | baseline | current | delta |\n|---|---|---:|---:|---:|\n",
    );
    let mut regressions = Vec::new();
    for sc in &new.scenarios {
        let base = old.find(&sc.name, sc.codec.as_deref());
        for (metric, value) in &sc.metrics {
            // Run-shape parameters, not measurements.
            if matches!(metric.as_str(), "clients" | "rounds" | "workers" | "users" | "requests") {
                continue;
            }
            let Some(prev) = base.and_then(|b| b.metric(metric)) else {
                out.push_str(&format!("| {} | {metric} | - | {value:.3} | new |\n", sc.label()));
                continue;
            };
            let delta = if prev > 0.0 { (value - prev) / prev * 100.0 } else { 0.0 };
            out.push_str(&format!(
                "| {} | {metric} | {prev:.3} | {value:.3} | {delta:+.1}% |\n",
                sc.label()
            ));
            if metric == "ipc_rtt_p99_ms" && *value > prev * RTT_P99_BUDGET {
                regressions.push(format!(
                    "{}: ipc_rtt_p99_ms {prev:.3} -> {value:.3} ms ({delta:+.1}%)",
                    sc.label()
                ));
            }
        }
    }
    (out, regressions)
}

/// The bench re-exec worker: one SimCompute shard executor process,
/// spawned by [`scenario_ipc`] through the `ccm bench --worker` path.
fn worker_main(args: &Args) -> Result<()> {
    let manifest = bench_manifest();
    let sim = bench_sim(&manifest, 200);
    let mut cfg = bench_cfg();
    cfg.shards = args.usize("shards", 1)?.max(1);
    cfg.ipc_codec = IpcCodec::parse(&args.str_env("ipc-codec", "CCM_IPC_CODEC", "binary"))?;
    let shard = args.usize("shard", 0)?;
    let factory: BackendFactory<'static> = Box::new(move || Ok(Box::new(sim) as Box<dyn Compute>));
    crate::server::run_worker(&manifest, factory, cfg, shard, None)
}

/// In-process serve path: `clients` connections each running `rounds`
/// of add_context(64 tokens) + query, per-round latency recorded
/// client-side.
fn scenario_inprocess(
    name: &str,
    clients: usize,
    rounds: usize,
    delay_us: u64,
) -> Result<Scenario> {
    let manifest = bench_manifest();
    let sim = bench_sim(&manifest, delay_us);
    let cfg = bench_cfg();
    let (ready_tx, ready_rx) = channel();
    let server = std::thread::spawn(move || {
        serve_with_backend(&manifest, Box::new(sim), cfg, Some(ready_tx))
    });
    let addr = ready_rx.recv().context("server ready")?;
    let (lat, secs) = run_clients(&addr, clients, rounds)?;
    let mut admin = Client::connect(&addr)?;
    admin.shutdown()?;
    // lint: allow(unwrap) — a panicked server thread is a bench bug;
    // re-raise it.
    server.join().expect("server thread")?;
    let mut sc = Scenario::new(name, None);
    push_round_metrics(&mut sc, &lat, secs, clients, rounds);
    Ok(sc)
}

/// Two worker processes behind the shard IPC hop under `codec`. The
/// client-side round metrics include the process boundary; the
/// `ipc_rtt_*` metrics are the proxy's own dispatch→reply samples from
/// merged stats (worst worker — the tail governs), measuring exactly
/// the hop the codec changes.
fn scenario_ipc(codec: IpcCodec, clients: usize, rounds: usize) -> Result<Scenario> {
    let workers = 2usize;
    let mut cfg = bench_cfg();
    cfg.ipc_codec = codec;
    let exe = std::env::current_exe()?;
    let mode = WorkerMode::Spawn {
        count: workers,
        launcher: Box::new(move |shard| {
            let mut cmd = std::process::Command::new(&exe);
            cmd.arg("bench")
                .arg("--worker")
                .arg("--shard")
                .arg(shard.to_string())
                .arg("--shards")
                .arg(workers.to_string())
                .arg("--ipc-codec")
                .arg(codec.name());
            cmd
        }),
    };
    let (ready_tx, ready_rx) = channel();
    let server = std::thread::spawn(move || serve_workers(cfg, mode, Some(ready_tx)));
    let addr = ready_rx.recv().context("front-end ready")?;
    wait_workers_up(&addr, workers)?;
    let (lat, secs) = run_clients(&addr, clients, rounds)?;
    let mut admin = Client::connect(&addr)?;
    let stats = admin.stats()?;
    if stats.get("shard_restarts")?.usize()? != 0 {
        bail!("a worker crashed mid-bench; RTT numbers would be meaningless");
    }
    let mut p50: Vec<f64> = Vec::new();
    let mut p99: Vec<f64> = Vec::new();
    for row in stats.get("per_worker")?.arr()? {
        // Null until a worker has samples; an idle worker stays null.
        if let Some(v) = row.opt("rtt_p50_ms").and_then(|v| v.f64().ok()) {
            p50.push(v);
        }
        if let Some(v) = row.opt("rtt_p99_ms").and_then(|v| v.f64().ok()) {
            p99.push(v);
        }
    }
    if p50.is_empty() || p99.is_empty() {
        bail!("no worker reported RTT percentiles");
    }
    admin.shutdown()?;
    // lint: allow(unwrap) — a panicked server thread is a bench bug;
    // re-raise it.
    server.join().expect("server thread")?;
    let mut sc = Scenario::new("ipc-2worker", Some(codec.name()));
    push_round_metrics(&mut sc, &lat, secs, clients, rounds);
    sc.push("workers", workers as f64);
    sc.push("ipc_rtt_p50_ms", p50.iter().copied().fold(f64::MIN, f64::max));
    sc.push("ipc_rtt_p99_ms", p99.iter().copied().fold(f64::MIN, f64::max));
    Ok(sc)
}

fn push_round_metrics(sc: &mut Scenario, lat_us: &[u64], secs: f64, clients: usize, rounds: usize) {
    sc.push("clients", clients as f64);
    sc.push("rounds", rounds as f64);
    sc.push("rounds_per_sec", (clients * rounds) as f64 / secs);
    let ms = |q: usize| percentile(lat_us, q).unwrap_or(0) as f64 / 1e3;
    sc.push("round_p50_ms", ms(50));
    sc.push("round_p99_ms", ms(99));
}

/// Drive `clients` concurrent connections for `rounds` each; returns
/// per-round latencies (µs, all clients pooled) and the wall time.
fn run_clients(addr: &str, clients: usize, rounds: usize) -> Result<(Vec<u64>, f64)> {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || -> Result<Vec<u64>> {
            let mut client = Client::connect(&addr)?;
            let session = format!("bench{c}");
            let ctx: Vec<i32> = (0..CTX_TOKENS).map(|i| 4 + ((c * 7 + i) % 500) as i32).collect();
            let mut lat = Vec::with_capacity(rounds);
            for r in 0..rounds {
                let t = Instant::now();
                client.add_context(&session, &ctx)?;
                let next = client.query(&session, &[4 + (r % 500) as i32], 3)?;
                if next.len() != 3 {
                    bail!("query returned {} candidates", next.len());
                }
                lat.push(t.elapsed().as_micros() as u64);
            }
            Ok(lat)
        }));
    }
    let mut all = Vec::new();
    for h in handles {
        // lint: allow(unwrap) — a panicked client thread is a bench
        // bug; re-raise it.
        all.extend(h.join().expect("bench client thread")?);
    }
    Ok((all, t0.elapsed().as_secs_f64()))
}

/// Poll merged stats until every `per_worker` row reports up (`ready`
/// fires at front-end bind, while workers may still be spawning).
fn wait_workers_up(addr: &str, workers: usize) -> Result<()> {
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut admin = Client::connect(addr)?;
    loop {
        let stats = admin.stats()?;
        let up = stats
            .get("per_worker")?
            .arr()?
            .iter()
            .filter(|row| row.opt("up") == Some(&Json::Bool(true)))
            .count();
        if up == workers {
            return Ok(());
        }
        if Instant::now() >= deadline {
            bail!("only {up}/{workers} workers up within 30s");
        }
        std::thread::sleep(Duration::from_millis(25));
    }
}

pub(crate) fn bench_cfg() -> ServerConfig {
    let scenario = bench_scenario();
    let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(scenario.comp_len_max));
    cfg.max_batch = 8;
    cfg.max_wait = Duration::from_millis(1);
    cfg.max_pending = 4096;
    cfg
}

pub(crate) fn bench_sim(manifest: &Manifest, delay_us: u64) -> SimCompute {
    let mut sim = SimCompute::from_manifest(manifest);
    sim.compress_delay = Duration::from_micros(delay_us);
    sim.infer_delay = Duration::from_micros(delay_us);
    sim
}

/// Roomier chunk/input caps than the coordinator bench so each round
/// carries [`CTX_TOKENS`] context tokens — the payload size where the
/// codec choice matters.
fn bench_scenario() -> ScenarioConfig {
    ScenarioConfig {
        t_max: 8,
        chunk_max: CTX_TOKENS,
        comp_len_max: 4,
        input_max: 96,
        seq_train: 224,
        mem_slots: 32,
        batch_train: 8,
        infer_batches: vec![1, 8],
        decode_cache: 96,
        rmt_unroll: 4,
        rmt_mem: 4,
    }
}

pub(crate) fn bench_manifest() -> Manifest {
    use crate::model::manifest::{ModelConfig, ParamLayout};
    Manifest {
        config_name: "bench".into(),
        dir: std::path::PathBuf::from("."),
        model: ModelConfig {
            name: "bench".into(),
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 512,
            max_pos: 512,
            lora_rank: 8,
            lora_alpha: 16.0,
            pad_id: 0,
            bos_id: 1,
            sep_id: 2,
            comp_id: 3,
            d_head: 32,
        },
        scenario: bench_scenario(),
        base_layout: ParamLayout { total: 1, entries: vec![] },
        lora_layout: ParamLayout { total: 1, entries: vec![] },
        artifacts: vec![],
        mask_goldens: vec![],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(p99: f64) -> Report {
        let mut r = Report::new(7);
        let mut sc = Scenario::new("ipc-2worker", Some("binary"));
        sc.push("rounds_per_sec", 1000.0);
        sc.push("ipc_rtt_p99_ms", p99);
        r.scenarios.push(sc);
        r
    }

    #[test]
    fn compare_renders_deltas_and_flags_budget_violations() {
        let (table, regressions) = compare(&report(1.0), &report(1.2));
        assert!(table
            .contains("| ipc-2worker[binary] | ipc_rtt_p99_ms | 1.000 | 1.200 | +20.0% |"));
        assert!(regressions.is_empty(), "20% is inside the 25% budget: {regressions:?}");

        let (_, regressions) = compare(&report(1.0), &report(1.3));
        assert_eq!(regressions.len(), 1, "30% must trip the budget");
        assert!(regressions[0].contains("ipc_rtt_p99_ms"));
    }

    #[test]
    fn compare_marks_metrics_without_a_baseline_as_new() {
        let mut old = report(1.0);
        old.scenarios.clear();
        let (table, regressions) = compare(&old, &report(1.0));
        assert!(table.contains("| new |"));
        assert!(regressions.is_empty(), "no baseline means nothing to regress against");
    }
}
