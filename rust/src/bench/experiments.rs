//! Driver functions, one per paper table/figure. See DESIGN.md §6 for
//! the experiment index and the qualitative "shape" each must reproduce.

use std::time::Instant;

use anyhow::Result;

use super::{AdapterSpec, ExpContext};
use crate::baselines::rmt::RmtEngine;
use crate::baselines::summarize::summarize;
use crate::compress::{CompressItem, Engine, InferItem};
use crate::coordinator::session::SessionPolicy;
use crate::coordinator::Coordinator;
use crate::datagen::{by_name, OnlineSample, Split};
use crate::eval::memacct;
use crate::eval::streaming::{stream_ppl, StreamEvalConfig};
use crate::eval::Evaluator;
use crate::masks::{MergeScheme, Method};
use crate::memory::MemoryStore;
use crate::model::Checkpoint;
use crate::training::pack::PackPolicy;
use crate::util::cli::Args;

const METHODS: [Method; 6] = [
    Method::NoContext,
    Method::Full,
    Method::Gist,
    Method::Compressive,
    Method::CcmConcat,
    Method::CcmMerge,
];

fn fmt_metric(acc: f64, ppl: f64) -> String {
    if acc.is_nan() {
        format!("{ppl:.3}")
    } else {
        format!("{:.1}%", acc * 100.0)
    }
}

/// Evaluate one (method, dataset, t); adapters are trained/cached per
/// method on the dataset itself (the paper's per-application setting).
fn eval_method(
    ctx: &mut ExpContext,
    method: Method,
    dataset: &str,
    mixture: &str,
    t: usize,
    comp_len: usize,
) -> Result<crate::eval::EvalReport> {
    let ck = match method {
        Method::Full | Method::NoContext => ctx.base(super::UNIFIED)?,
        _ => ctx.adapter(&AdapterSpec::new(method, comp_len, mixture))?,
    };
    let ds =
        by_name(dataset, ctx.budget.seed, &ctx.manifest().scenario, ctx.manifest().model.vocab)?;
    let policy = PackPolicy::new(method, comp_len);
    let ev = Evaluator::new(&ctx.rt, &ck);
    let n = ctx.budget.eval_n;
    if ds.is_multi_choice() {
        ev.accuracy(&policy, ds.as_ref(), t, n)
    } else {
        ev.perplexity(&policy, ds.as_ref(), t, n)
    }
}

/// Figure 7 (+ Tables 23-25): method comparison over time steps.
pub fn fig7_methods(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let datasets = args.list("dataset", &["metaicl", "lamp", "dialog"]);
    let comp_len = args.usize("comp-len", 2)?;
    for dataset in &datasets {
        let mixture = dataset.clone();
        let ts = ctx.budget.t_values.clone();
        let mut rows = Vec::new();
        for &t in &ts {
            let mut row = vec![t.to_string()];
            for method in METHODS {
                let r = eval_method(ctx, method, dataset, &mixture, t, comp_len)?;
                row.push(fmt_metric(r.accuracy, r.perplexity));
            }
            rows.push(row);
        }
        let header =
            ["t", "nocontext", "full", "gist-online", "compressive", "ccm-concat", "ccm-merge"];
        ctx.emit(
            &format!("fig7-{dataset}"),
            &format!(
                "Figure 7 / Tables 23-25 analogue — {dataset} ({} test ids, comp_len {comp_len})",
                ctx.budget.eval_n
            ),
            &header,
            &rows,
        )?;
    }
    Ok(())
}

/// Figure 6: performance vs peak KV memory over time steps (MetaICL).
pub fn fig6_memory_perf(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let dataset = args.str("dataset", "metaicl");
    let comp_len = args.usize("comp-len", 2)?;
    let ts = ctx.budget.t_values.clone();
    let mut rows = Vec::new();
    for &t in &ts {
        for method in [Method::Full, Method::CcmConcat, Method::CcmMerge, Method::NoContext] {
            let r = eval_method(ctx, method, &dataset, &dataset, t, comp_len)?;
            rows.push(vec![
                t.to_string(),
                method.name().to_string(),
                format!("{:.1}", r.peak_kv_bytes as f64 / 1024.0),
                fmt_metric(r.accuracy, r.perplexity),
            ]);
        }
    }
    ctx.emit(
        "fig6",
        &format!("Figure 6 analogue — {dataset}: performance vs peak KV (KiB)"),
        &["t", "method", "peak KV (KiB)", "metric"],
        &rows,
    )?;
    Ok(())
}

/// Figure 10: the same memory-vs-performance pareto on all datasets.
pub fn fig10_all_datasets(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let comp_len = args.usize("comp-len", 2)?;
    let mut rows = Vec::new();
    let t = *ctx.budget.t_values.last().unwrap();
    for dataset in ["metaicl", "lamp", "dialog"] {
        for method in METHODS {
            let r = eval_method(ctx, method, dataset, dataset, t, comp_len)?;
            rows.push(vec![
                dataset.to_string(),
                method.name().to_string(),
                format!("{:.1}", r.peak_kv_bytes as f64 / 1024.0),
                fmt_metric(r.accuracy, r.perplexity),
            ]);
        }
    }
    ctx.emit(
        "fig10",
        &format!("Figure 10 analogue — memory vs performance at t={t}"),
        &["dataset", "method", "peak KV (KiB)", "metric"],
        &rows,
    )?;
    Ok(())
}

/// Table 1: serving throughput — full context vs CCM-concat vs CCM-merge.
pub fn table1_throughput(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let dataset = args.str("dataset", "metaicl");
    let comp_len = args.usize("comp-len", 2)?;
    let t = *ctx.budget.t_values.last().unwrap();
    let n_sessions = args.usize("sessions", 24)?;
    let kv_budget_mb = args.f32("kv-budget-mb", 64.0)?;
    let m = ctx.manifest().model.clone();
    let sc = ctx.manifest().scenario.clone();
    let ds = by_name(&dataset, ctx.budget.seed, &sc, m.vocab)?;
    let samples: Vec<OnlineSample> = (0..n_sessions)
        .map(|i| ds.sample(Split::Test, i % ds.n_identities(Split::Test), t))
        .collect();

    let mut rows = Vec::new();
    for method in [Method::Full, Method::CcmConcat, Method::CcmMerge] {
        let ck = match method {
            Method::Full => ctx.base(super::UNIFIED)?,
            _ => ctx.adapter(&AdapterSpec::new(method, comp_len, &dataset))?,
        };
        // Context KV length per session at step t.
        let lc: Vec<usize> = samples[0].chunks.iter().map(|c| c.len()).collect();
        let (_, inf_entries) = memacct::peak_kv_entries(method, &lc, sc.input_max, comp_len);
        let ctx_kv = inf_entries - sc.input_max.min(inf_entries);
        let per_session_bytes = memacct::kv_bytes(&m, ctx_kv) as f64;
        let max_batch = ((kv_budget_mb as f64 * 1e6) / per_session_bytes.max(1.0)) as usize;

        // Measured serving throughput: queries/sec at artifact batch 8.
        let t0 = Instant::now();
        let served;
        match method {
            Method::Full => {
                // Full context scores via the packed parallel forward.
                let ev = Evaluator::new(&ctx.rt, &ck);
                let policy = PackPolicy::new(Method::Full, comp_len);
                let items: Vec<(&OnlineSample, Option<&[i32]>)> =
                    samples.iter().map(|s| (s, None)).collect();
                ev.forward(&policy, &items)?;
                served = samples.len();
            }
            _ => {
                // CCM serving path: sessions already compressed; time the
                // query phase (the steady-state online cost).
                let policy = match method {
                    Method::CcmMerge => SessionPolicy::merge(comp_len),
                    _ => SessionPolicy::concat(comp_len),
                };
                let mut coord =
                    Coordinator::new(&ctx.rt, &ck, policy, 8, std::time::Duration::ZERO)?;
                for (i, s) in samples.iter().enumerate() {
                    let sess = format!("s{i}");
                    for c in &s.chunks {
                        coord.add_context(&sess, c.clone());
                    }
                }
                coord.run_until_idle()?;
                let tq = Instant::now();
                for (i, s) in samples.iter().enumerate() {
                    coord.query(&format!("s{i}"), s.input_with_target());
                }
                coord.run_until_idle()?;
                served = samples.len();
                rows.push(vec![
                    format!("{} (incl. compression)", method.name()),
                    format!("{:.1}", served as f64 / t0.elapsed().as_secs_f64()),
                    String::new(),
                    String::new(),
                ]);
                let _ = tq;
            }
        }
        let secs = t0.elapsed().as_secs_f64();
        rows.push(vec![
            method.name().to_string(),
            format!("{:.1}", served as f64 / secs),
            ctx_kv.to_string(),
            max_batch.to_string(),
        ]);
    }
    ctx.emit(
        "table1",
        &format!(
            "Table 1 analogue — {dataset} t={t}, {n_sessions} sessions, {kv_budget_mb} MB KV budget"
        ),
        &["method", "throughput (samples/s)", "context KV len", "max batch @ budget"],
        &rows,
    )?;
    Ok(())
}

/// Table 3 + Table 17: complexity accounting (analytic, from memacct).
pub fn table3_complexity(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let comp_len = args.usize("comp-len", 2)?;
    let m = ctx.manifest().model.clone();
    let (lc, li) = (20usize, 16usize);
    let mut rows = Vec::new();
    for t in [1usize, 2, 4, 8, 16] {
        let lens = vec![lc; t];
        for method in [Method::Full, Method::Gist, Method::CcmConcat, Method::CcmMerge] {
            let (c_peak, i_peak) = memacct::peak_kv_entries(method, &lens, li, comp_len);
            let (c_macs, i_macs) = memacct::step_attn_macs(&m, method, &lens, li, comp_len);
            rows.push(vec![
                t.to_string(),
                method.name().to_string(),
                c_peak.to_string(),
                i_peak.to_string(),
                format!("{:.2}M", c_macs as f64 / 1e6),
                format!("{:.2}M", i_macs as f64 / 1e6),
            ]);
        }
    }
    ctx.emit(
        "table3",
        "Table 3 analogue — KV entries & attention MACs per online step",
        &["t", "method", "comp KV", "infer KV", "comp MACs", "infer MACs"],
        &rows,
    )?;

    // Table 17: breakeven inference length per comp_len.
    let mut rows = Vec::new();
    for cl in [1usize, 2, 4, 8] {
        let th = memacct::breakeven_inference_tokens(&m, 50, cl, 16);
        rows.push(vec![cl.to_string(), format!("x{}", 50 / cl), th.to_string()]);
    }
    ctx.emit(
        "table17",
        "Table 17 analogue — FLOPs breakeven vs <COMP> length (lc=50, t=16)",
        &["comp len", "compression factor", "breakeven inference tokens"],
        &rows,
    )?;
    Ok(())
}

/// Table 4: effect of adapter training data sources.
pub fn table4_datasources(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let comp_len = args.usize("comp-len", 2)?;
    let t = *ctx.budget.t_values.last().unwrap();
    let mixtures = ["dialog", "dialog+metaicl", "dialog+metaicl+lamp"];
    let eval_sets = ["metaicl", "lamp", "dialog"];
    let mut rows = Vec::new();
    for mixture in mixtures {
        let mut row = vec![mixture.to_string()];
        for dataset in eval_sets {
            // Gap vs the full-context model trained on the same mixture.
            let r_ccm = {
                let ck = ctx.adapter(&AdapterSpec::new(Method::CcmConcat, comp_len, mixture))?;
                let ds = by_name(dataset, ctx.budget.seed, &ctx.manifest().scenario, 512)?;
                let ev = Evaluator::new(&ctx.rt, &ck);
                let p = PackPolicy::new(Method::CcmConcat, comp_len);
                if ds.is_multi_choice() {
                    ev.accuracy(&p, ds.as_ref(), t, ctx.budget.eval_n)?
                } else {
                    ev.perplexity(&p, ds.as_ref(), t, ctx.budget.eval_n)?
                }
            };
            let r_full = {
                let ck = ctx.base(super::UNIFIED)?;
                let ds = by_name(dataset, ctx.budget.seed, &ctx.manifest().scenario, 512)?;
                let ev = Evaluator::new(&ctx.rt, &ck);
                let p = PackPolicy::new(Method::Full, comp_len);
                if ds.is_multi_choice() {
                    ev.accuracy(&p, ds.as_ref(), t, ctx.budget.eval_n)?
                } else {
                    ev.perplexity(&p, ds.as_ref(), t, ctx.budget.eval_n)?
                }
            };
            let gap = if r_ccm.accuracy.is_nan() {
                format!("{:+.3}", r_ccm.perplexity - r_full.perplexity)
            } else {
                format!("{:+.1}%", (r_ccm.accuracy - r_full.accuracy) * 100.0)
            };
            row.push(gap);
        }
        rows.push(row);
    }
    ctx.emit(
        "table4",
        &format!(
            "Table 4 analogue — compression gap vs full context at t={t} by training mixture"
        ),
        &["training mixture", "metaicl", "lamp", "dialog"],
        &rows,
    )?;
    Ok(())
}

/// Table 5 (+21): conditional vs default LoRA.
pub fn table5_cond_lora(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let comp_len = args.usize("comp-len", 2)?;
    let datasets = args.list("dataset", &["metaicl"]);
    let t = *ctx.budget.t_values.last().unwrap();
    for dataset in &datasets {
        let mut rows = Vec::new();
        for method in [Method::CcmConcat, Method::CcmMerge, Method::Gist] {
            let mut row = vec![method.name().to_string()];
            for conditional in [false, true] {
                let mut spec = AdapterSpec::new(method, comp_len, dataset);
                spec.conditional = conditional;
                let ck = ctx.adapter(&spec)?;
                let ds = by_name(dataset, ctx.budget.seed, &ctx.manifest().scenario, 512)?;
                let ev = Evaluator::new(&ctx.rt, &ck);
                let mut p = PackPolicy::new(method, comp_len);
                p.conditional = conditional;
                let r = if ds.is_multi_choice() {
                    ev.accuracy(&p, ds.as_ref(), t, ctx.budget.eval_n)?
                } else {
                    ev.perplexity(&p, ds.as_ref(), t, ctx.budget.eval_n)?
                };
                row.push(fmt_metric(r.accuracy, r.perplexity));
            }
            rows.push(row);
        }
        ctx.emit(
            &format!("table5-{dataset}"),
            &format!("Table 5/21 analogue — default vs conditional LoRA on {dataset} (t={t})"),
            &["method", "default LoRA", "conditional LoRA"],
            &rows,
        )?;
    }
    Ok(())
}

/// Table 6: fixed-context compression (Gisting) vs CCM peak memory.
pub fn table6_fixed_context(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let comp_len = args.usize("comp-len", 2)?;
    let dataset = args.str("dataset", "metaicl");
    let t = *ctx.budget.t_values.last().unwrap();
    let mut rows = Vec::new();
    for method in [Method::Full, Method::Gist, Method::CcmConcat, Method::CcmMerge] {
        let r = eval_method(ctx, method, &dataset, &dataset, t, comp_len)?;
        rows.push(vec![
            method.name().to_string(),
            fmt_metric(r.accuracy, r.perplexity),
            format!("{:.1}", r.peak_kv_bytes as f64 / 1024.0),
        ]);
    }
    ctx.emit(
        "table6",
        &format!("Table 6 analogue — fixed-context compression vs CCM ({dataset}, t={t})"),
        &["method", "metric", "peak KV (KiB)"],
        &rows,
    )?;
    Ok(())
}

/// Table 7: RougeL + accuracy of generations.
pub fn table7_rougel(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let comp_len = args.usize("comp-len", 2)?;
    let dataset = args.str("dataset", "metaicl");
    let t = *ctx.budget.t_values.last().unwrap();
    let n = ctx.budget.eval_n.min(20); // generation is forward-per-token
    let mut rows = Vec::new();
    for method in METHODS {
        let ck = match method {
            Method::Full | Method::NoContext => ctx.base(super::UNIFIED)?,
            _ => ctx.adapter(&AdapterSpec::new(method, comp_len, &dataset))?,
        };
        let ds = by_name(&dataset, ctx.budget.seed, &ctx.manifest().scenario, 512)?;
        let ev = Evaluator::new(&ctx.rt, &ck);
        let p = PackPolicy::new(method, comp_len);
        let rouge = ev.rouge_l(&p, ds.as_ref(), t, n)?;
        let acc = ev.accuracy(&p, ds.as_ref(), t, ctx.budget.eval_n)?;
        rows.push(vec![
            method.name().to_string(),
            format!("{:.1}", rouge * 100.0),
            format!("{:.1}%", acc.accuracy * 100.0),
        ]);
    }
    ctx.emit(
        "table7",
        &format!("Table 7 analogue — RougeL & accuracy ({dataset}, t={t}, n={n})"),
        &["method", "RougeL", "accuracy"],
        &rows,
    )?;
    Ok(())
}

/// Table 8 (+22): recurrent compression (RMT shape) vs CCM.
pub fn table8_recurrent(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let comp_len = args.usize("comp-len", 2)?;
    let dataset = args.str("dataset", "metaicl");
    let t = args.usize("t-rmt", 4)?; // RMT artifact unrolls rmt_unroll chunks
    let n = ctx.budget.eval_n.min(25);
    let mut rows = Vec::new();

    // CCM rows: accuracy + measured training throughput.
    for method in [Method::CcmConcat, Method::CcmMerge] {
        let ck = ctx.adapter(&AdapterSpec::new(method, comp_len, &dataset))?;
        let ds = by_name(&dataset, ctx.budget.seed, &ctx.manifest().scenario, 512)?;
        let ev = Evaluator::new(&ctx.rt, &ck);
        let r = ev.accuracy(&PackPolicy::new(method, comp_len), ds.as_ref(), t, n)?;
        // Measure CCM train ms/sample over a few steps.
        let trainer = crate::training::Trainer::new(&ctx.rt);
        let mut ck2 = ck.clone();
        let rep = trainer.train_ccm(
            &mut ck2,
            &PackPolicy::new(method, comp_len),
            &crate::datagen::corpus::Mixture::parse(&dataset),
            3,
            1e-3,
            1,
        )?;
        let lc: Vec<usize> = ds.sample(Split::Test, 0, t).chunks.iter().map(|c| c.len()).collect();
        let kv = memacct::peak_kv_bytes(&ctx.manifest().model, method, &lc, 16, comp_len);
        rows.push(vec![
            method.name().to_string(),
            format!("{:.1}%", r.accuracy * 100.0),
            format!("{:.1}", kv as f64 / 1024.0),
            format!("{:.0}", rep.ms_per_sample),
        ]);
    }

    // RMT row: sequential per-chunk model calls.
    let (rmt_ck, rmt_ms) = ctx.rmt(&dataset)?;
    let rmt = RmtEngine::new(&ctx.rt, &rmt_ck);
    let ds = by_name(&dataset, ctx.budget.seed, &ctx.manifest().scenario, 512)?;
    let mut correct = 0usize;
    for id in 0..n {
        let s = ds.sample(Split::Test, id, t);
        let (choice, _calls) = rmt.choose(&s)?;
        correct += usize::from(choice == s.correct);
    }
    rows.push(vec![
        "rmt/autocompressor".to_string(),
        format!("{:.1}%", correct as f64 / n as f64 * 100.0),
        format!("{:.1}", rmt.mem_kv_bytes() as f64 / 1024.0),
        format!("{:.0}", rmt_ms),
    ]);

    // Reference rows.
    for method in [Method::NoContext, Method::Full] {
        let r = eval_method(ctx, method, &dataset, &dataset, t, comp_len)?;
        rows.push(vec![
            method.name().to_string(),
            format!("{:.1}%", r.accuracy * 100.0),
            format!("{:.1}", r.peak_kv_bytes as f64 / 1024.0),
            "-".to_string(),
        ]);
    }
    ctx.emit(
        "table8",
        &format!("Table 8/22 analogue — recurrent baseline vs CCM ({dataset}, t={t}, n={n})"),
        &["method", "accuracy", "KV (KiB)", "train ms/sample"],
        &rows,
    )?;
    Ok(())
}

/// Table 9: text summarization (MemoryBank) vs CCM on dialogue.
pub fn table9_summarization(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let comp_len = args.usize("comp-len", 2)?;
    let dataset = "dialog";
    let t = *ctx.budget.t_values.last().unwrap();
    let n = ctx.budget.eval_n;
    let budget_tokens = args.usize("summary-budget", 16)?;
    let mut rows = Vec::new();

    for method in [Method::NoContext, Method::Full, Method::CcmConcat, Method::CcmMerge] {
        let r = eval_method(ctx, method, dataset, dataset, t, comp_len)?;
        let lens = match method {
            Method::NoContext => 0usize,
            Method::Full => 8 * 12, // avg raw context tokens (approx label)
            Method::CcmConcat => t * comp_len,
            _ => comp_len,
        };
        rows.push(vec![
            method.name().to_string(),
            format!("{:.3}", r.perplexity),
            lens.to_string(),
        ]);
    }

    // MemoryBank baseline: summarize chunks to `budget_tokens`, score the
    // target with the summary as the (single-chunk) raw context.
    let ck = ctx.base(super::UNIFIED)?;
    let ds = by_name(dataset, ctx.budget.seed, &ctx.manifest().scenario, 512)?;
    let ev = Evaluator::new(&ctx.rt, &ck);
    let mut total_nll = 0.0;
    let mut total_tok = 0usize;
    for id in 0..n.min(ds.n_identities(Split::Test)) {
        let mut s = ds.sample(Split::Test, id, t);
        let summary = summarize(&s.chunks, budget_tokens);
        s.chunks = vec![summary];
        let p = PackPolicy::new(Method::Full, comp_len);
        let items = [(&s, None)];
        let logits = &ev.forward(&p, &items)?[0];
        let row = crate::training::pack::pack_row(&p, &ctx.manifest().scenario, &s, None)?;
        let ll = Evaluator::row_avg_loglik(logits, &row.tokens, row.target_start, row.target_len);
        total_nll += -ll * row.target_len as f64;
        total_tok += row.target_len;
    }
    rows.push(vec![
        "memorybank (extractive)".to_string(),
        format!("{:.3}", (total_nll / total_tok as f64).exp()),
        budget_tokens.to_string(),
    ]);

    ctx.emit(
        "table9",
        &format!("Table 9 analogue — summarization vs CCM on dialog (t={t})"),
        &["method", "perplexity", "compressed context length"],
        &rows,
    )?;
    Ok(())
}

/// Table 15: one unified adapter evaluated across all applications.
pub fn table15_unified(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let comp_len = args.usize("comp-len", 2)?;
    let _mixture = super::UNIFIED;
    let t = *ctx.budget.t_values.last().unwrap();
    let mut rows = Vec::new();
    for dataset in ["metaicl", "lamp", "dialog"] {
        let mut row = vec![dataset.to_string()];
        for method in METHODS {
            let ck = match method {
                Method::Full | Method::NoContext => ctx.base(super::UNIFIED)?,
                _ => ctx.adapter(&AdapterSpec::new(method, comp_len, mixture))?,
            };
            let ds = by_name(dataset, ctx.budget.seed, &ctx.manifest().scenario, 512)?;
            let ev = Evaluator::new(&ctx.rt, &ck);
            let p = PackPolicy::new(method, comp_len);
            let r = if ds.is_multi_choice() {
                ev.accuracy(&p, ds.as_ref(), t, ctx.budget.eval_n)?
            } else {
                ev.perplexity(&p, ds.as_ref(), t, ctx.budget.eval_n)?
            };
            row.push(fmt_metric(r.accuracy, r.perplexity));
        }
        rows.push(row);
    }
    ctx.emit(
        "table15",
        &format!("Table 15 analogue — unified adapter (trained on {mixture}) at t={t}"),
        &["eval dataset", "nocontext", "full", "gist", "compressive", "ccm-concat", "ccm-merge"],
        &rows,
    )?;
    Ok(())
}

/// Table 16: merge-function design — arithmetic average vs EMA.
pub fn table16_ema(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let comp_len = args.usize("comp-len", 2)?;
    let dataset = args.str("dataset", "dialog");
    let ts = ctx.budget.t_values.clone();
    let mut rows = Vec::new();
    for scheme in [MergeScheme::Avg, MergeScheme::Ema(0.5)] {
        let mut spec = AdapterSpec::new(Method::CcmMerge, comp_len, &dataset);
        spec.scheme = scheme;
        let ck = ctx.adapter(&spec)?;
        let ds = by_name(&dataset, ctx.budget.seed, &ctx.manifest().scenario, 512)?;
        let ev = Evaluator::new(&ctx.rt, &ck);
        let mut p = PackPolicy::new(Method::CcmMerge, comp_len);
        p.scheme = scheme;
        let mut row = vec![format!("{scheme:?}")];
        for &t in &ts {
            let r = if ds.is_multi_choice() {
                ev.accuracy(&p, ds.as_ref(), t, ctx.budget.eval_n)?
            } else {
                ev.perplexity(&p, ds.as_ref(), t, ctx.budget.eval_n)?
            };
            row.push(fmt_metric(r.accuracy, r.perplexity));
        }
        rows.push(row);
    }
    let mut header = vec!["scheme".to_string()];
    header.extend(ts.iter().map(|t| format!("t={t}")));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    ctx.emit(
        "table16",
        &format!("Table 16 analogue — merge scheme on {dataset}"),
        &header_refs,
        &rows,
    )?;
    Ok(())
}

/// Table 18: `<COMP>` token length sweep.
pub fn table18_comp_len(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let dataset = args.str("dataset", "metaicl");
    let t = *ctx.budget.t_values.last().unwrap();
    let lens = [1usize, 2, 4];
    let mut rows = Vec::new();
    for method in [Method::CcmConcat, Method::CcmMerge] {
        let mut row = vec![method.name().to_string()];
        for &cl in &lens {
            let r = eval_method(ctx, method, &dataset, &dataset, t, cl)?;
            row.push(fmt_metric(r.accuracy, r.perplexity));
        }
        rows.push(row);
    }
    ctx.emit(
        "table18",
        &format!("Table 18 analogue — <COMP> length sweep on {dataset} (t={t})"),
        &["method", "cl=1", "cl=2", "cl=4"],
        &rows,
    )?;
    Ok(())
}

/// Tables 19/20: larger / differently-shaped model (run with
/// `--config big` or `--config wide`; this driver evaluates the current
/// config and labels it).
pub fn table19_scale(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let comp_len = args.usize("comp-len", 2)?;
    let dataset = args.str("dataset", "metaicl");
    let t = *ctx.budget.t_values.last().unwrap();
    let name = ctx.manifest().model.name.clone();
    let mut rows = Vec::new();
    for method in METHODS {
        let r = eval_method(ctx, method, &dataset, &dataset, t, comp_len)?;
        rows.push(vec![
            method.name().to_string(),
            fmt_metric(r.accuracy, r.perplexity),
            format!("{:.1}", r.peak_kv_bytes as f64 / 1024.0),
        ]);
    }
    ctx.emit(
        &format!("table19-{name}"),
        &format!("Table 19/20 analogue — config '{name}' on {dataset} (t={t})"),
        &["method", "metric", "peak KV (KiB)"],
        &rows,
    )?;
    Ok(())
}

/// Figure 8: streaming perplexity vs StreamingLLM at equal KV budget.
pub fn fig8_streaming(ctx: &mut ExpContext, args: &Args) -> Result<()> {
    let _mixture = super::UNIFIED;
    let ck = ctx.adapter(&AdapterSpec::new(
        Method::CcmConcat,
        ctx.manifest().scenario.comp_len_max,
        super::UNIFIED,
    ))?;
    let mut cfg = StreamEvalConfig::for_manifest(ctx.manifest());
    cfg.n_tokens = args.usize("stream-tokens", 1536)?;
    let ccm_rep = stream_ppl(&ctx.rt, &ck, &cfg, ctx.budget.seed, true)?;
    let base_rep = stream_ppl(&ctx.rt, &ck, &cfg, ctx.budget.seed, false)?;
    let mut rows = Vec::new();
    let pairs = ccm_rep.curve.iter().zip(base_rep.curve.iter());
    for ((tok, ppl_c), (_, ppl_b)) in pairs {
        rows.push(vec![tok.to_string(), format!("{ppl_c:.3}"), format!("{ppl_b:.3}")]);
    }
    rows.push(vec![
        "final".into(),
        format!("{:.3} ({} compressions)", ccm_rep.final_ppl, ccm_rep.compressions),
        format!("{:.3}", base_rep.final_ppl),
    ]);
    ctx.emit(
        "fig8",
        &format!(
            "Figure 8 analogue — streaming PPL, KV budget {} (CCM mem {} slots)",
            cfg.max_kv, cfg.mem_slots
        ),
        &["tokens", "CCM-concat", "StreamingLLM"],
        &rows,
    )?;
    Ok(())
}

/// Helper shared by the serve example/bench: compress a full session and
/// time both phases (used for ad-hoc profiling, not a paper table).
pub fn time_session(
    rt: &crate::runtime::Runtime,
    ck: &Checkpoint,
    sample: &OnlineSample,
    comp_len: usize,
) -> Result<(f64, f64)> {
    let engine = Engine::new(rt, ck, comp_len)?;
    let m = &rt.manifest.model;
    let sc = &rt.manifest.scenario;
    let mut mem = MemoryStore::concat(m.n_layers, sc.mem_slots, m.d_model, comp_len);
    let mut pos = 0usize;
    let t0 = Instant::now();
    for c in &sample.chunks {
        let item = CompressItem { mem: &mem, chunk: c, pos_start: pos };
        let h = engine.compress(std::slice::from_ref(&item))?.remove(0);
        mem.update(&h)?;
        pos += c.len() + comp_len;
    }
    let t_comp = t0.elapsed().as_secs_f64() * 1e3;
    let it = sample.input_with_target();
    let t1 = Instant::now();
    let item = InferItem { mem: &mem, tokens: &it, pos_start: pos };
    engine.infer(std::slice::from_ref(&item))?;
    Ok((t_comp, t1.elapsed().as_secs_f64() * 1e3))
}
