//! Attention-KV memory + FLOPs accounting (Table 3, Figure 5/6, Table 17).
//!
//! The paper's efficiency claims are about *lengths of attention KV*
//! during the compression and inference passes of each method. This
//! module computes those lengths exactly from the actual chunk lengths,
//! then converts to bytes (2·L·D·4 per KV entry, f32) and attention MACs.

use crate::masks::Method;
use crate::model::manifest::ModelConfig;

/// Peak KV entries during (compression pass, inference pass) at step t.
/// `lc`: chunk token lengths 1..=t, `li`: input length, `cl`: <COMP> len.
pub fn peak_kv_entries(
    method: Method,
    lc: &[usize],
    li: usize,
    cl: usize,
) -> (usize, usize) {
    let t = lc.len();
    let total_c: usize = lc.iter().sum();
    let last = lc.last().copied().unwrap_or(0);
    match method {
        // No compression pass; inference attends the raw context.
        Method::Full => (0, total_c + li),
        Method::NoContext => (0, li),
        // Fixed-context compression (Gisting): recompress ALL of C(t).
        Method::Gist => (total_c + cl * t, cl * t + li),
        // CCM-concat: compress c(t) against Mem(t-1); infer on Mem(t).
        Method::CcmConcat => ((t - 1) * cl + last + cl, t * cl + li),
        // CCM-merge: fixed memory.
        Method::CcmMerge => (cl + last + cl, cl + li),
        // Online Compressive Transformer: pooled slots accumulate like
        // concat, but pooling reads the raw chunk (no comp tokens).
        Method::Compressive => ((t - 1) * cl + last, t * cl + li),
    }
}

/// Bytes for `entries` KV entries (keys + values, f32).
pub fn kv_bytes(m: &ModelConfig, entries: usize) -> usize {
    2 * m.n_layers * entries * m.d_model * 4
}

/// Peak KV bytes across both passes (the Figure 6 x-axis).
pub fn peak_kv_bytes(m: &ModelConfig, method: Method, lc: &[usize], li: usize, cl: usize) -> usize {
    let (c, i) = peak_kv_entries(method, lc, li, cl);
    kv_bytes(m, c.max(i))
}

/// Attention MACs for a pass: every query attends `kv` entries.
/// 2 matmuls (q·kᵀ, p·v) of q·kv·d per head group = 2·q·kv·D per layer.
pub fn attn_macs(m: &ModelConfig, q: usize, kv: usize) -> u64 {
    2 * (m.n_layers as u64) * (q as u64) * (kv as u64) * (m.d_model as u64)
}

/// Attention MACs of the compression + inference passes at step t.
pub fn step_attn_macs(
    m: &ModelConfig,
    method: Method,
    lc: &[usize],
    li: usize,
    cl: usize,
) -> (u64, u64) {
    let t = lc.len();
    let total_c: usize = lc.iter().sum();
    let last = lc.last().copied().unwrap_or(0);
    match method {
        Method::Full => (0, attn_macs(m, total_c + li, total_c + li)),
        Method::NoContext => (0, attn_macs(m, li, li)),
        Method::Gist => (
            attn_macs(m, total_c + cl * t, total_c + cl * t),
            attn_macs(m, li, cl * t + li),
        ),
        Method::CcmConcat => (
            attn_macs(m, last + cl, (t - 1) * cl + last + cl),
            attn_macs(m, li, t * cl + li),
        ),
        Method::CcmMerge => {
            (attn_macs(m, last + cl, cl + last + cl), attn_macs(m, li, cl + li))
        }
        Method::Compressive => {
            (attn_macs(m, last, (t - 1) * cl + last), attn_macs(m, li, t * cl + li))
        }
    }
}

/// Table 17: compression overhead vs attention-FLOPs savings. Returns the
/// minimum inference token length where CCM's saving outweighs the
/// `<COMP>` forward overhead. Model-forward MACs per token ~ 2·P where P =
/// non-embedding params; savings per inference token ~ attention over
/// (full_kv - compressed_kv).
pub fn breakeven_inference_tokens(m: &ModelConfig, lc: usize, cl: usize, t: usize) -> usize {
    // Overhead: forwarding cl extra tokens per chunk, t chunks.
    let params_per_layer = 4 * m.d_model * m.d_model + 2 * m.d_model * m.d_ff;
    let fwd_macs_per_tok = (m.n_layers * params_per_layer) as u64;
    let overhead = (t * cl) as u64 * fwd_macs_per_tok;
    // Savings per inference token: attention over full context vs memory.
    let full_kv = t * lc;
    let mem_kv = t * cl;
    let save_per_tok = attn_macs(m, 1, full_kv) - attn_macs(m, 1, mem_kv);
    if save_per_tok == 0 {
        return usize::MAX;
    }
    overhead.div_ceil(save_per_tok) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 512,
            max_pos: 512,
            lora_rank: 8,
            lora_alpha: 16.0,
            pad_id: 0,
            bos_id: 1,
            sep_id: 2,
            comp_id: 3,
            d_head: 32,
        }
    }

    #[test]
    fn orderings_match_the_paper() {
        let m = model();
        let lc = vec![20usize; 8];
        let (li, cl) = (16, 2);
        let peak = |meth| peak_kv_bytes(&m, meth, &lc, li, cl);
        // merge < concat < gist(fixed) <= full — Figure 6 / Table 6 shape.
        assert!(peak(Method::CcmMerge) < peak(Method::CcmConcat));
        assert!(peak(Method::CcmConcat) < peak(Method::Gist));
        assert!(peak(Method::Gist) <= peak(Method::Full) + kv_bytes(&m, cl * 8));
        assert!(peak(Method::NoContext) < peak(Method::CcmMerge));
    }

    #[test]
    fn merge_peak_is_constant_in_t() {
        let m = model();
        let p1 = peak_kv_bytes(&m, Method::CcmMerge, &vec![20; 2], 16, 2);
        let p2 = peak_kv_bytes(&m, Method::CcmMerge, &vec![20; 16], 16, 2);
        assert_eq!(p1, p2);
        // While concat grows linearly.
        let c1 = peak_kv_bytes(&m, Method::CcmConcat, &vec![20; 2], 16, 2);
        let c2 = peak_kv_bytes(&m, Method::CcmConcat, &vec![20; 16], 16, 2);
        assert!(c2 > c1);
    }

    #[test]
    fn flops_complexities() {
        let m = model();
        let (comp_c, inf_c) = step_attn_macs(&m, Method::CcmConcat, &vec![50; 16], 16, 1);
        let (comp_g, inf_g) = step_attn_macs(&m, Method::Gist, &vec![50; 16], 16, 1);
        // Fixed-context compression reprocesses everything: much larger.
        assert!(comp_g > 10 * comp_c, "{comp_g} vs {comp_c}");
        assert!(inf_g <= inf_c); // gist inference attends only gists
        let (_, inf_full) = step_attn_macs(&m, Method::Full, &vec![50; 16], 16, 1);
        assert!(inf_full > inf_c);
    }

    #[test]
    fn breakeven_grows_with_comp_len() {
        let m = model();
        let th1 = breakeven_inference_tokens(&m, 50, 1, 16);
        let th2 = breakeven_inference_tokens(&m, 50, 2, 16);
        let th4 = breakeven_inference_tokens(&m, 50, 4, 16);
        assert!(th1 < th2 && th2 < th4, "{th1} {th2} {th4}");
    }
}
