//! Evaluation harness: accuracy (multi-choice log-likelihood scoring, the
//! MetaICL protocol), perplexity, RougeL generation, and the per-method
//! KV-memory accounting — everything Figures 6/7/10 and Tables 5-9/15-25
//! are built from.
//!
//! The same machinery scores LIVE traffic: `ccm loadgen`
//! (`crate::bench::loadgen`) samples sessions mid-replay and reuses
//! [`rouge`] + [`memacct`] to report compression quality under load —
//! docs/SCENARIOS.md maps each paper table/figure to its serving
//! scenario.

pub mod memacct;
pub mod rouge;
pub mod streaming;

use anyhow::Result;

use crate::datagen::{OnlineDataset, OnlineSample, Split};
use crate::masks::Method;
use crate::model::Checkpoint;
use crate::runtime::{Runtime, Value};
use crate::tensor::Tensor;
use crate::training::pack::{pack_batch, PackPolicy};

#[derive(Debug, Clone)]
pub struct EvalReport {
    pub method: Method,
    pub t: usize,
    pub n: usize,
    /// Accuracy in [0,1] (multi-choice datasets) or NaN.
    pub accuracy: f64,
    /// Perplexity (language datasets) or NaN.
    pub perplexity: f64,
    /// Peak attention-KV bytes across compression+inference (Figure 6).
    pub peak_kv_bytes: usize,
}

pub struct Evaluator<'rt> {
    pub rt: &'rt Runtime,
    pub ck: &'rt Checkpoint,
    /// Use the Pallas-kernel forward artifact (b=1) instead of the fused
    /// jnp forward — same math, exercises the L1 kernel end-to-end.
    pub use_pallas: bool,
}

impl<'rt> Evaluator<'rt> {
    pub fn new(rt: &'rt Runtime, ck: &'rt Checkpoint) -> Evaluator<'rt> {
        Evaluator { rt, ck, use_pallas: false }
    }

    fn forward_name(&self, b: usize) -> String {
        if self.use_pallas && b == 1 {
            "ccm_forward_pallas_b1".into()
        } else {
            format!("ccm_forward_b{b}")
        }
    }

    fn eval_batch(&self, n: usize) -> usize {
        crate::compress::pick_batch(&self.rt.manifest.scenario.infer_batches, n.max(1))
    }

    /// Run the parallel forward over packed rows; returns logits [B,S,V].
    pub fn forward(
        &self,
        policy: &PackPolicy,
        samples: &[(&OnlineSample, Option<&[i32]>)],
    ) -> Result<Vec<Tensor>> {
        let manifest = &self.rt.manifest;
        let mut out = Vec::with_capacity(samples.len());
        let mut i = 0;
        while i < samples.len() {
            let b = self.eval_batch(samples.len() - i);
            let group = &samples[i..(i + b).min(samples.len())];
            i += group.len();
            let batch = pack_batch(policy, manifest, group, b)?;
            let nb = manifest.base_layout.total;
            let nl = manifest.lora_layout.total;
            let outs = self.rt.execute_f32(
                &self.forward_name(b),
                &[
                    Value::vec_f32(&[nb], self.ck.base.data.clone())?,
                    Value::vec_f32(&[nl], self.ck.lora.data.clone())?,
                    Value::I32(batch.tokens),
                    Value::I32(batch.comp_slot),
                    Value::F32(batch.gate),
                    Value::I32(batch.pos),
                    Value::F32(batch.mask),
                    Value::F32(batch.merge_p),
                ],
            )?;
            let logits = &outs[0]; // [b, S, V]
            let (s, v) = (logits.shape[1], logits.shape[2]);
            for bi in 0..group.len() {
                let mut t = Tensor::zeros(&[s, v]);
                let n = s * v;
                t.data.copy_from_slice(&logits.data[bi * n..(bi + 1) * n]);
                out.push(t);
            }
        }
        Ok(out)
    }

    /// Average log-likelihood of `len` target tokens starting at
    /// `target_start` in the packed row.
    pub fn row_avg_loglik(logits: &Tensor, tokens: &[i32], target_start: usize, len: usize) -> f64 {
        let mut total = 0.0f64;
        for i in 0..len {
            let row = logits.row(&[target_start + i - 1]);
            let tgt = tokens[target_start + i] as usize;
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln() + mx;
            total += (row[tgt] - lse) as f64;
        }
        total / len as f64
    }

    /// Multi-choice accuracy at time step t over `n` test identities
    /// (the MetaICL protocol: argmax over per-choice average LL).
    pub fn accuracy(
        &self,
        policy: &PackPolicy,
        ds: &dyn OnlineDataset,
        t: usize,
        n: usize,
    ) -> Result<EvalReport> {
        let n = n.min(ds.n_identities(Split::Test));
        let mut correct = 0usize;
        let mut peak = 0usize;
        let mut ids = Vec::with_capacity(n);
        for id in 0..n {
            ids.push(ds.sample(Split::Test, id, t));
        }
        // Flatten every (sample, choice) into one item stream so the
        // forward saturates the largest batch variant (§Perf L3).
        let items: Vec<(&OnlineSample, Option<&[i32]>)> = ids
            .iter()
            .flat_map(|s| s.choices.iter().map(move |c| (s, Some(c.as_slice()))))
            .collect();
        let logits = self.forward(policy, &items)?;
        let mut li = 0usize;
        for sample in &ids {
            let mut best = (f64::NEG_INFINITY, 0usize);
            for (ci, choice) in sample.choices.iter().enumerate() {
                let row = crate::training::pack::pack_row(
                    policy,
                    &self.rt.manifest.scenario,
                    sample,
                    Some(choice),
                )?;
                let ll = Self::row_avg_loglik(
                    &logits[li],
                    &row.tokens,
                    row.target_start,
                    row.target_len,
                );
                li += 1;
                if ll > best.0 {
                    best = (ll, ci);
                }
            }
            correct += usize::from(best.1 == sample.correct);
            let lc: Vec<usize> = sample.chunks.iter().map(|c| c.len()).collect();
            peak = peak.max(memacct::peak_kv_bytes(
                &self.rt.manifest.model,
                policy.method,
                &lc,
                sample.input.len() + 1,
                policy.comp_len,
            ));
        }
        Ok(EvalReport {
            method: policy.method,
            t,
            n,
            accuracy: correct as f64 / n as f64,
            perplexity: f64::NAN,
            peak_kv_bytes: peak,
        })
    }

    /// Perplexity on the next turn at time step t (DailyDialog protocol).
    pub fn perplexity(
        &self,
        policy: &PackPolicy,
        ds: &dyn OnlineDataset,
        t: usize,
        n: usize,
    ) -> Result<EvalReport> {
        let n = n.min(ds.n_identities(Split::Test));
        let mut total_nll = 0.0f64;
        let mut total_toks = 0usize;
        let mut peak = 0usize;
        let samples: Vec<OnlineSample> =
            (0..n).map(|id| ds.sample(Split::Test, id, t)).collect();
        let items: Vec<(&OnlineSample, Option<&[i32]>)> =
            samples.iter().map(|s| (s, None)).collect();
        let logits = self.forward(policy, &items)?;
        for (sample, lg) in samples.iter().zip(&logits) {
            let row = crate::training::pack::pack_row(
                policy,
                &self.rt.manifest.scenario,
                sample,
                None,
            )?;
            let ll = Self::row_avg_loglik(lg, &row.tokens, row.target_start, row.target_len);
            total_nll += -ll * row.target_len as f64;
            total_toks += row.target_len;
            let lc: Vec<usize> = sample.chunks.iter().map(|c| c.len()).collect();
            peak = peak.max(memacct::peak_kv_bytes(
                &self.rt.manifest.model,
                policy.method,
                &lc,
                sample.input.len() + sample.target.len(),
                policy.comp_len,
            ));
        }
        Ok(EvalReport {
            method: policy.method,
            t,
            n,
            accuracy: f64::NAN,
            perplexity: (total_nll / total_toks as f64).exp(),
            peak_kv_bytes: peak,
        })
    }

    /// Greedy generation via the parallel forward (uniform across
    /// methods), for the RougeL comparison (Table 7).
    pub fn generate(
        &self,
        policy: &PackPolicy,
        sample: &OnlineSample,
        max_new: usize,
    ) -> Result<Vec<i32>> {
        let mut gen: Vec<i32> = Vec::new();
        for _ in 0..max_new {
            let items = [(sample, Some(gen.as_slice()))];
            let logits = &self.forward(policy, &items)?[0];
            let row = crate::training::pack::pack_row(
                policy,
                &self.rt.manifest.scenario,
                sample,
                Some(&gen),
            )?;
            // Next-token logits at the last real token position.
            let last = row.target_start + gen.len() - 1;
            let lrow = logits.row(&[last]);
            let mut best = (f32::NEG_INFINITY, 0usize);
            for (i, &x) in lrow.iter().enumerate() {
                if x > best.0 {
                    best = (x, i);
                }
            }
            if best.1 as i32 == self.rt.manifest.model.pad_id {
                break;
            }
            gen.push(best.1 as i32);
        }
        Ok(gen)
    }

    /// Mean RougeL of greedy generations vs targets over n identities.
    pub fn rouge_l(
        &self,
        policy: &PackPolicy,
        ds: &dyn OnlineDataset,
        t: usize,
        n: usize,
    ) -> Result<f64> {
        let n = n.min(ds.n_identities(Split::Test));
        let mut total = 0.0f64;
        for id in 0..n {
            let sample = ds.sample(Split::Test, id, t);
            let gen = self.generate(&policy.clone(), &sample, sample.target.len() + 1)?;
            total += rouge::rouge_l(&gen, &sample.target);
        }
        Ok(total / n as f64)
    }
}
