//! RougeL over token sequences (Table 7's generation metric).
//!
//! Standard LCS-based precision/recall/F1. Operates on token ids — the
//! synthetic vocabulary has no casing/synonym structure, so token-level
//! LCS is the faithful analogue.

/// Longest common subsequence length.
pub fn lcs_len(a: &[i32], b: &[i32]) -> usize {
    if a.is_empty() || b.is_empty() {
        return 0;
    }
    let mut prev = vec![0usize; b.len() + 1];
    let mut cur = vec![0usize; b.len() + 1];
    for &x in a {
        for (j, &y) in b.iter().enumerate() {
            cur[j + 1] = if x == y { prev[j] + 1 } else { cur[j].max(prev[j + 1]) };
        }
        std::mem::swap(&mut prev, &mut cur);
        cur.iter_mut().for_each(|v| *v = 0);
    }
    prev[b.len()]
}

/// RougeL F1 (beta = 1).
pub fn rouge_l(candidate: &[i32], reference: &[i32]) -> f64 {
    let l = lcs_len(candidate, reference) as f64;
    if l == 0.0 {
        return 0.0;
    }
    let p = l / candidate.len() as f64;
    let r = l / reference.len() as f64;
    2.0 * p * r / (p + r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lcs_matches_bruteforce_on_small_inputs() {
        fn brute(a: &[i32], b: &[i32]) -> usize {
            if a.is_empty() || b.is_empty() {
                0
            } else if a[0] == b[0] {
                1 + brute(&a[1..], &b[1..])
            } else {
                brute(&a[1..], b).max(brute(a, &b[1..]))
            }
        }
        crate::util::proptest::check("lcs-brute", 40, |rng| {
            let n = rng.range(0, 8);
            let m = rng.range(0, 8);
            let a: Vec<i32> = (0..n).map(|_| rng.range(0, 4) as i32).collect();
            let b: Vec<i32> = (0..m).map(|_| rng.range(0, 4) as i32).collect();
            crate::prop_assert!(
                lcs_len(&a, &b) == brute(&a, &b),
                "lcs mismatch on {a:?} vs {b:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn rouge_extremes() {
        assert_eq!(rouge_l(&[1, 2, 3], &[1, 2, 3]), 1.0);
        assert_eq!(rouge_l(&[4, 5], &[1, 2, 3]), 0.0);
        let r = rouge_l(&[1, 9, 2, 9], &[1, 2]);
        assert!(r > 0.5 && r < 1.0);
    }
}
