//! Streaming evaluation (Figure 8/9): next-token perplexity over an
//! unbounded stream under a hard KV budget.
//!
//! CCM mode keeps `[attention sink | compressed memory | recent window]`;
//! when the budget trips, the oldest window block is compressed into the
//! memory (CCM-concat with FIFO slot eviction). The StreamingLLM baseline
//! keeps `[sink | recent window]` only, with the *same total budget*.
//! Position ids are reassigned from 0 at every scoring step, following
//! Xiao et al. (2023).

use anyhow::{ensure, Result};

use crate::compress::{CompressItem, Engine, InferItem};
use crate::datagen::stream::StreamGen;
use crate::memory::window::{Overflow, StreamWindow};
use crate::memory::MemoryStore;
use crate::model::Checkpoint;
use crate::runtime::Runtime;

#[derive(Debug, Clone)]
pub struct StreamEvalConfig {
    /// Hard KV budget (token-equivalents) — identical for both systems.
    pub max_kv: usize,
    /// Compressed-memory slot cap (CCM only; baseline gets these back as
    /// raw window budget, keeping total equal).
    pub mem_slots: usize,
    /// Oldest tokens compressed per compression step.
    pub compress_block: usize,
    /// `<COMP>` slots produced per compression.
    pub comp_len: usize,
    pub n_sink: usize,
    /// Tokens scored per step (streamed in blocks for throughput).
    pub score_block: usize,
    /// Total stream length to evaluate.
    pub n_tokens: usize,
}

impl StreamEvalConfig {
    /// Sized for the artifacts' input_max; mirrors the paper's 160-budget
    /// setup at our scale.
    pub fn for_manifest(m: &crate::model::manifest::Manifest) -> StreamEvalConfig {
        let input_max = m.scenario.input_max;
        StreamEvalConfig {
            max_kv: input_max - 6,
            mem_slots: m.scenario.comp_len_max * 2,
            compress_block: 8,
            comp_len: m.scenario.comp_len_max,
            n_sink: 2,
            score_block: 6,
            n_tokens: 2048,
        }
    }
}

#[derive(Debug, Clone)]
pub struct StreamReport {
    /// (tokens seen, cumulative perplexity) checkpoints.
    pub curve: Vec<(u64, f64)>,
    pub final_ppl: f64,
    pub compressions: u64,
    pub mean_kv: f64,
}

/// Run the streaming evaluation. `use_ccm=false` gives the StreamingLLM
/// baseline at equal budget.
pub fn stream_ppl(
    rt: &Runtime,
    ck: &Checkpoint,
    cfg: &StreamEvalConfig,
    seed: u64,
    use_ccm: bool,
) -> Result<StreamReport> {
    let m = &rt.manifest;
    let engine = Engine::new(rt, ck, cfg.comp_len)?;
    let mut gen = StreamGen::new(seed, m.model.vocab);
    let mut window = if use_ccm {
        StreamWindow::ccm(cfg.max_kv, cfg.mem_slots, cfg.compress_block, cfg.comp_len, cfg.n_sink)
    } else {
        StreamWindow::streaming_llm(cfg.max_kv, cfg.n_sink)
    };
    let mut mem = MemoryStore::concat(
        m.model.n_layers,
        m.scenario.mem_slots,
        m.model.d_model,
        cfg.comp_len,
    );
    // Sanity: scoring input must fit the artifact.
    ensure!(
        cfg.max_kv + cfg.score_block <= m.scenario.input_max + cfg.mem_slots,
        "budget too large for input_max"
    );

    let mut total_nll = 0.0f64;
    let mut total_tok = 0u64;
    let mut curve = Vec::new();
    let mut compressions = 0u64;
    let mut kv_acc = 0.0f64;
    let mut kv_n = 0u64;

    while (total_tok as usize) < cfg.n_tokens {
        let block = gen.take(cfg.score_block);
        // Score the block given [sink | window | block-prefix] + memory.
        let mut tokens: Vec<i32> = Vec::with_capacity(cfg.max_kv + cfg.score_block);
        tokens.extend_from_slice(&window.sink);
        tokens.extend_from_slice(&window.window);
        let ctx_len = tokens.len();
        tokens.extend_from_slice(&block);
        ensure!(tokens.len() <= m.scenario.input_max, "scoring input too long");
        let item = InferItem { mem: &mem, tokens: &tokens, pos_start: 0 };
        let logits = &engine.infer(std::slice::from_ref(&item))?[0];
        for (i, &tok) in block.iter().enumerate() {
            let pos = ctx_len + i;
            if pos == 0 {
                continue; // first-ever token has no context
            }
            let row = logits.row(&[pos - 1]);
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = row.iter().map(|&x| (x - mx).exp()).sum::<f32>().ln() + mx;
            total_nll += -((row[tok as usize] - lse) as f64);
            total_tok += 1;
        }
        kv_acc += (window.kv_size() + block.len()) as f64;
        kv_n += 1;
        // Stream the block into the window; compress overflow.
        for tok in block {
            if let Overflow::Compress(blocks) = window.push(tok) {
                for b in blocks {
                    let pos0 = window.sink.len();
                    let item = CompressItem { mem: &mem, chunk: &b, pos_start: pos0 };
                    let h = engine.compress(std::slice::from_ref(&item))?.remove(0);
                    if mem.free_slots() != usize::MAX && mem.free_slots() < cfg.comp_len {
                        mem.evict_chunks(1);
                    }
                    mem.update(&h)?;
                    compressions += 1;
                    let evict_slots = window.note_compressed(cfg.comp_len);
                    if evict_slots > 0 {
                        mem.evict_chunks(evict_slots.div_ceil(cfg.comp_len));
                        window.mem_slots_used = mem.len();
                    } else {
                        window.mem_slots_used = mem.len();
                    }
                }
            }
        }
        if total_tok % 512 < cfg.score_block as u64 {
            curve.push((total_tok, (total_nll / total_tok as f64).exp()));
        }
    }
    let final_ppl = (total_nll / total_tok as f64).exp();
    curve.push((total_tok, final_ppl));
    Ok(StreamReport { curve, final_ppl, compressions, mean_kv: kv_acc / kv_n as f64 })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_fits_artifacts() {
        // Pure-shape test (no runtime): the default config must satisfy
        // the ensure! bounds for the main scenario sizes.
        let sc = crate::model::manifest::ScenarioConfig {
            t_max: 12,
            chunk_max: 24,
            comp_len_max: 4,
            input_max: 32,
            seq_train: 384,
            mem_slots: 48,
            batch_train: 16,
            infer_batches: vec![1, 8],
            decode_cache: 96,
            rmt_unroll: 4,
            rmt_mem: 4,
        };
        let mc = crate::model::manifest::ModelConfig {
            name: "x".into(),
            vocab: 512,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            d_ff: 512,
            max_pos: 512,
            lora_rank: 8,
            lora_alpha: 16.0,
            pad_id: 0,
            bos_id: 1,
            sep_id: 2,
            comp_id: 3,
            d_head: 32,
        };
        let manifest = crate::model::manifest::Manifest {
            config_name: "x".into(),
            dir: std::path::PathBuf::from("."),
            model: mc,
            scenario: sc,
            base_layout: crate::model::manifest::ParamLayout { total: 1, entries: vec![] },
            lora_layout: crate::model::manifest::ParamLayout { total: 1, entries: vec![] },
            artifacts: vec![],
            mask_goldens: vec![],
        };
        let cfg = StreamEvalConfig::for_manifest(&manifest);
        // sink + window(max) + score_block <= input_max
        assert!(cfg.max_kv + cfg.score_block <= manifest.scenario.input_max + cfg.mem_slots);
        assert!(cfg.n_sink + cfg.mem_slots < cfg.max_kv);
    }
}
