//! # ccm — Compressed Context Memory for Online Language Model Interaction
//!
//! Production-shaped reproduction of Kim et al., ICLR 2024
//! (<https://arxiv.org/abs/2312.03414>), built as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernels for the masked
//!   attention-with-memory-slots hot spot and the fused conditional-LoRA
//!   projection.
//! * **L2** (`python/compile/model.py`) — the Transformer LM with the
//!   parallelized CCM forward, lowered once to HLO text artifacts.
//! * **L3** (this crate) — the online-inference coordinator: sessions
//!   holding per-identity compressed memory, a dynamic batcher, the
//!   compression scheduler, streaming mode, the training driver that
//!   executes the AOT train-step artifacts, and the evaluation +
//!   benchmark harnesses that regenerate every table/figure of the paper.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! Rust binary is self-contained.
//!
//! Unsafe code (raw syscalls in `server/poll.rs`, the checkpoint byte
//! cast in `model/store.rs`) is fenced by `// SAFETY:` comments —
//! machine-enforced here by clippy and repo-wide by `ccm-lint`
//! (`docs/INVARIANTS.md`).
//!
//! ## Quick tour
//!
//! ```no_run
//! use ccm::runtime::Runtime;
//!
//! let rt = Runtime::from_config("main").unwrap();
//! // feed context chunks, compress, infer — see examples/quickstart.rs
//! ```

#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod baselines;
pub mod bench;
pub mod compress;
pub mod coordinator;
pub mod datagen;
pub mod eval;
pub mod masks;
pub mod memory;
pub mod model;
pub mod runtime;
pub mod server;
pub mod tensor;
pub mod training;
pub mod util;

use anyhow::{bail, Result};
use util::cli::Args;

/// `ccm train --phase lm|ccm|rmt` — run a training phase and save the
/// checkpoint under `runs/<config>/`.
pub fn cli_train(args: &Args) -> Result<()> {
    let config = args.str("config", "main");
    let budget = bench::Budget::from_args(args)?;
    let mut ctx = bench::ExpContext::new(&config, budget)?;
    let phase = args.str("phase", "lm");
    let mixture = args.str("mixture", "metaicl+dialog");
    match phase.as_str() {
        "lm" => {
            ctx.base(&mixture)?;
        }
        "ccm" => {
            let method = masks::Method::parse(&args.str("method", "ccm-concat"))?;
            let comp_len = args.usize("comp-len", 2)?;
            let mut spec = bench::AdapterSpec::new(method, comp_len, &mixture);
            spec.scheme = masks::MergeScheme::parse(&args.str("scheme", "avg"))?;
            spec.conditional = !args.bool("unconditional");
            ctx.adapter(&spec)?;
        }
        "rmt" => {
            let (_, ms) = ctx.rmt(&mixture)?;
            crate::info!("rmt trained: {ms:.0} ms/sample");
        }
        other => bail!("unknown phase {other:?} (lm|ccm|rmt)"),
    }
    Ok(())
}

/// `ccm eval --dataset metaicl --method ccm-concat --t 8`
pub fn cli_eval(args: &Args) -> Result<()> {
    let config = args.str("config", "main");
    let budget = bench::Budget::from_args(args)?;
    let mut ctx = bench::ExpContext::new(&config, budget)?;
    let dataset = args.str("dataset", "metaicl");
    let comp_len = args.usize("comp-len", 2)?;
    let methods = args.list("method", &["nocontext", "full", "ccm-concat", "ccm-merge"]);
    let ts = ctx.budget.t_values.clone();
    for method_name in methods {
        let method = masks::Method::parse(&method_name)?;
        for &t in &ts {
            let ck = match method {
                masks::Method::Full | masks::Method::NoContext => ctx.base(bench::UNIFIED)?,
                _ => ctx.adapter(&bench::AdapterSpec::new(method, comp_len, &dataset))?,
            };
            let ds = datagen::by_name(
                &dataset,
                ctx.budget.seed,
                &ctx.rt.manifest.scenario,
                ctx.rt.manifest.model.vocab,
            )?;
            let ev = eval::Evaluator::new(&ctx.rt, &ck);
            let p = training::pack::PackPolicy::new(method, comp_len);
            let r = if ds.is_multi_choice() {
                ev.accuracy(&p, ds.as_ref(), t, ctx.budget.eval_n)?
            } else {
                ev.perplexity(&p, ds.as_ref(), t, ctx.budget.eval_n)?
            };
            println!(
                "{dataset} {method_name} t={t}: acc {:.3} ppl {:.3} peakKV {:.1} KiB",
                r.accuracy,
                r.perplexity,
                r.peak_kv_bytes as f64 / 1024.0
            );
        }
    }
    Ok(())
}

/// Artifacts the serving path pre-compiles at startup. With one shard
/// this happens before the port is bound; with `--shards N` each shard
/// warms up concurrently inside its executor thread after the port is
/// bound (see [`serve_backend_factories`]), so early requests queue on
/// their shard until its warmup completes instead of seeing
/// connection-refused.
pub const SERVE_WARMUP: [&str; 4] =
    ["compress_chunk_b1", "compress_chunk_b8", "infer_with_mem_b1", "infer_with_mem_b8"];

/// Build `shards` backend factories for [`server::serve_sharded`]:
/// each factory runs inside its shard's executor thread and creates a
/// full runtime from `config`, loads (or seeds) the checkpoint,
/// pre-compiles the serving artifacts, and returns an owned engine —
/// one runtime per shard, since PJRT runtimes are thread-bound.
/// Shards are deterministic replicas (same checkpoint path / init
/// seed). `ccm serve --shards N` and `examples/serve.rs` share this.
pub fn serve_backend_factories(
    config: &str,
    ckpt_path: &str,
    seed: u64,
    comp_len: usize,
    shards: usize,
) -> Vec<server::BackendFactory<'static>> {
    (0..shards)
        .map(|_| {
            let config = config.to_string();
            let ckpt_path = ckpt_path.to_string();
            let factory = move || -> Result<Box<dyn compress::Compute>> {
                let rt = runtime::Runtime::from_config(&config)?;
                let ck = load_or_init_checkpoint(&rt.manifest, &ckpt_path, seed)?;
                rt.warmup(&SERVE_WARMUP)?;
                let engine = compress::OwnedEngine::new(rt, ck, comp_len)?;
                Ok(Box::new(engine) as Box<dyn compress::Compute>)
            };
            Box::new(factory) as server::BackendFactory<'static>
        })
        .collect()
}

/// `ccm serve --port 7878 --method ccm-concat [--shards 4]
/// [--workers N | --worker-addr a:p,b:p] [--eviction
/// oldest|lru|largest-bytes] [--max-pending 256] [--kv-budget-mb 512]
/// [--session-ttl-secs 600] [--reactor auto|threads|epoll]
/// [--reactors auto|N] [--max-conns 16384]
/// [--ipc-codec json|binary]
/// [--strategy ccm|sliding-window|none] [--tiers SPEC]
/// [--respawn-backoff-min-ms 50] [--respawn-backoff-max-ms 2000]
/// [--shutdown-kill-after-secs 30] [--refusal-linger-secs 5]
/// [--accept-backoff-ms 50] [--hibernate-dir PATH]
/// [--hibernate-after-secs 60] [--orphan-grace-secs 120]`
///
/// `--strategy` sets the default compression tier admitted sessions
/// get when their first `context` carries no explicit `"strategy"`
/// field; `--tiers` tunes per-tier QoS and retention, e.g.
/// `ccm=8/4,sliding-window=4/2/16,none=1/1` as
/// `kind=refill/burst[/window_kv]` (token-bucket refill per second,
/// burst, and — for the sliding-window tier — its retained raw-KV
/// token budget). Both forward to spawned workers.
///
/// The five posture flags expose supervision/transport constants that
/// were previously baked in (defaults unchanged): the worker respawn
/// backoff schedule, the shutdown drain kill deadline, how long a
/// refused connection may linger while its refusal line drains, and
/// the accept pause after an EMFILE/ENFILE accept failure.
///
/// `--hibernate-dir` enables the tiered session lifecycle: sessions
/// idle past `--hibernate-after-secs` (default 60) spill their Mem(t)
/// to per-shard snapshot files under the directory, leave the KV
/// budget, and rehydrate transparently on their next touch; with a KV
/// budget, eviction victims are spilled before being dropped. Both
/// flags forward to spawned workers, as does `--orphan-grace-secs`
/// (the worker's first-connection orphan grace, default 120 s, which
/// also bounds the startup sweep of stale spill tmp files).
///
/// With `--shards N > 1`, each shard's executor thread owns a full
/// runtime + engine (PJRT runtimes are thread-bound); sessions route
/// to shards by a stable hash of the session id, and the KV budget is
/// partitioned across shards.
///
/// With `--workers N`, shards are promoted to worker PROCESSES: this
/// process keeps the connection front-end and spawns/supervises N
/// `ccm worker` children (respawning crashed ones — `shard_restarts`
/// in stats; while one is down its shard answers `shard_unavailable`).
/// `--worker-addr` connects to externally-started workers instead (one
/// `host:port` per shard, comma-separated; no spawning). The same
/// routing hash applies, so Mem(t) stays pinned to one worker. Backend
/// flags (`--method`, `--comp-len`, `--kv-budget-mb`, ...) are
/// forwarded to spawned workers; externally-started workers must be
/// given matching flags by the operator.
///
/// `--ipc-codec` selects the shard-IPC wire codec (default `binary`,
/// also via `CCM_IPC_CODEC`): spawned workers inherit it, and a worker
/// that declines the codec hello — any externally-started
/// `--worker-addr` peer that only speaks JSON — negotiates its
/// connection down to newline-framed JSON automatically. The
/// client-facing protocol is unaffected.
///
/// `--reactor` picks the connection front-end: `epoll` multiplexes
/// connections on polling reactor threads (the 10k-connection path),
/// `threads` keeps one blocking reader thread per connection. `auto`
/// (the default) resolves `CCM_SERVE_REACTOR`, then the platform
/// default (epoll on Linux). `--reactors` shards the epoll front-end
/// into N reactor threads with SO_REUSEPORT accept sharding (falling
/// back to single-listener round-robin handoff where unavailable);
/// `auto` (the default, also via `CCM_SERVE_REACTORS`) resolves to
/// min(4, cores). `--max-conns` bounds accepted connections globally
/// in every mode.
pub fn cli_serve(args: &Args) -> Result<()> {
    let config = args.str("config", "main");
    let manifest = model::Manifest::load(&model::artifact_dir(&config))?;
    let ckpt_path = args.str("checkpoint", "");
    let seed = args.u64("seed", 7)?;
    let comp_len = args.usize("comp-len", manifest.scenario.comp_len_max)?;
    let method = masks::Method::parse(&args.str("method", "ccm-concat"))?;
    let policy = match method {
        masks::Method::CcmMerge => coordinator::session::SessionPolicy::merge(comp_len),
        _ => coordinator::session::SessionPolicy::concat(comp_len),
    };
    let port = args.usize("port", 7878)?;
    let shards = args.usize("shards", 1)?.max(1);
    let mut cfg = server::ServerConfig::new(format!("127.0.0.1:{port}"), policy);
    cfg.shards = shards;
    cfg.eviction = coordinator::session::EvictionKind::parse(&args.str("eviction", "oldest"))?;
    cfg.max_batch = args.usize("max-batch", 8)?;
    cfg.max_wait = std::time::Duration::from_millis(args.u64("max-wait-ms", 2)?);
    cfg.max_pending = args.usize("max-pending", 256)?;
    let reactor = args.str_env("reactor", "CCM_SERVE_REACTOR", "auto");
    if reactor != "auto" {
        cfg.reactor = server::ReactorMode::parse(&reactor)?;
    }
    cfg.reactors = args
        .usize_env_auto("reactors", "CCM_SERVE_REACTORS", server::auto_reactors(), "auto")?
        .max(1);
    cfg.max_conns = args.usize("max-conns", cfg.max_conns)?;
    cfg.ipc_codec =
        server::IpcCodec::parse(&args.str_env("ipc-codec", "CCM_IPC_CODEC", "binary"))?;
    cfg.default_strategy = compress::StrategyKind::parse(&args.str("strategy", "ccm"))?;
    let tiers_spec = args.str("tiers", "");
    if !tiers_spec.is_empty() {
        cfg.tiers = compress::Tiers::parse(&tiers_spec)?;
    }
    cfg.respawn_backoff_min =
        std::time::Duration::from_millis(args.u64("respawn-backoff-min-ms", 50)?);
    cfg.respawn_backoff_max =
        std::time::Duration::from_millis(args.u64("respawn-backoff-max-ms", 2000)?);
    cfg.shutdown_kill_after =
        std::time::Duration::from_secs(args.u64("shutdown-kill-after-secs", 30)?);
    cfg.refusal_linger = std::time::Duration::from_secs(args.u64("refusal-linger-secs", 5)?);
    cfg.accept_backoff = std::time::Duration::from_millis(args.u64("accept-backoff-ms", 50)?);
    let kv_budget_mb = args.usize("kv-budget-mb", 0)?;
    if kv_budget_mb > 0 {
        cfg.kv_budget_bytes = Some(kv_budget_mb * (1 << 20));
    }
    let ttl_secs = args.u64("session-ttl-secs", 0)?;
    if ttl_secs > 0 {
        cfg.session_ttl = Some(std::time::Duration::from_secs(ttl_secs));
    }
    let hibernate_dir = args.str("hibernate-dir", "");
    if !hibernate_dir.is_empty() {
        cfg.hibernate_dir = Some(std::path::PathBuf::from(&hibernate_dir));
    }
    let hibernate_after_secs = args.u64("hibernate-after-secs", 0)?;
    if hibernate_after_secs > 0 {
        cfg.hibernate_after = Some(std::time::Duration::from_secs(hibernate_after_secs));
    }
    let orphan_grace_secs =
        args.u64("orphan-grace-secs", server::ORPHAN_GRACE_DEFAULT.as_secs())?;
    cfg.orphan_grace = std::time::Duration::from_secs(orphan_grace_secs);
    let workers = args.usize("workers", 0)?;
    let worker_addrs = args.list("worker-addr", &[]);
    if workers > 0 && !worker_addrs.is_empty() {
        bail!(
            "--workers (spawn {workers} supervised children) and --worker-addr (connect to \
             {} external workers) are mutually exclusive",
            worker_addrs.len()
        );
    }
    if workers > 0 || !worker_addrs.is_empty() {
        let mode = if worker_addrs.is_empty() {
            // Spawn `ccm worker` children from this same binary,
            // forwarding every backend-shaping flag so the worker
            // executors are configured exactly like in-process shards
            // would have been.
            let exe = std::env::current_exe()?;
            let mut forward: Vec<String> = vec![
                "worker".into(),
                "--shards".into(),
                workers.to_string(),
                "--config".into(),
                config.clone(),
                "--seed".into(),
                seed.to_string(),
                "--comp-len".into(),
                comp_len.to_string(),
                "--method".into(),
                args.str("method", "ccm-concat"),
                "--eviction".into(),
                args.str("eviction", "oldest"),
                "--max-batch".into(),
                cfg.max_batch.to_string(),
                "--max-wait-ms".into(),
                args.u64("max-wait-ms", 2)?.to_string(),
                "--max-pending".into(),
                cfg.max_pending.to_string(),
                "--kv-budget-mb".into(),
                kv_budget_mb.to_string(),
                "--session-ttl-secs".into(),
                ttl_secs.to_string(),
                "--ipc-codec".into(),
                cfg.ipc_codec.name().into(),
                "--strategy".into(),
                cfg.default_strategy.name().into(),
            ];
            if !tiers_spec.is_empty() {
                forward.push("--tiers".into());
                forward.push(tiers_spec.clone());
            }
            if !ckpt_path.is_empty() {
                forward.push("--checkpoint".into());
                forward.push(ckpt_path.clone());
            }
            if !hibernate_dir.is_empty() {
                forward.push("--hibernate-dir".into());
                forward.push(hibernate_dir.clone());
                forward.push("--hibernate-after-secs".into());
                forward.push(hibernate_after_secs.to_string());
            }
            forward.push("--orphan-grace-secs".into());
            forward.push(orphan_grace_secs.to_string());
            server::WorkerMode::Spawn {
                count: workers,
                launcher: Box::new(move |shard| {
                    let mut cmd = std::process::Command::new(&exe);
                    cmd.args(&forward).arg("--shard").arg(shard.to_string());
                    cmd
                }),
            }
        } else {
            server::WorkerMode::Connect { addrs: worker_addrs }
        };
        return server::serve_workers(cfg, mode, None);
    }
    if shards == 1 {
        let rt = runtime::Runtime::load(manifest)?;
        let ck = load_or_init_checkpoint(&rt.manifest, &ckpt_path, seed)?;
        rt.warmup(&SERVE_WARMUP)?;
        return server::serve(&rt, &ck, cfg, None);
    }
    let factories = serve_backend_factories(&config, &ckpt_path, seed, comp_len, shards);
    server::serve_sharded(&manifest, factories, cfg, None)
}

/// `ccm worker --shard K --shards N [--addr 127.0.0.1:0] [backend
/// flags as for serve]` — run ONE shard executor as its own process,
/// serving the newline-framed JSON IPC protocol for a `ccm serve
/// --workers N` front-end (which spawns this automatically; running it
/// by hand pairs with `--worker-addr`). Binds `--addr` (port 0 by
/// default) and prints the `CCM_WORKER_READY <addr>` handshake on
/// stdout once the listener is up. `--shard`/`--shards` position the
/// worker in the fleet: its slice of `--kv-budget-mb` partitions
/// exactly as for in-process shards. `--orphan-grace-secs` (default
/// 120) bounds how long the worker waits for its FIRST front-end
/// connection before concluding it is orphaned and exiting; with
/// `--hibernate-dir`/`--hibernate-after-secs` the worker spills idle
/// sessions to its shard's snapshot directory and sweeps a crashed
/// predecessor's stale `.tmp` spill files (older than the grace) at
/// startup.
pub fn cli_worker(args: &Args) -> Result<()> {
    let config = args.str("config", "main");
    let manifest = model::Manifest::load(&model::artifact_dir(&config))?;
    let ckpt_path = args.str("checkpoint", "");
    let seed = args.u64("seed", 7)?;
    let comp_len = args.usize("comp-len", manifest.scenario.comp_len_max)?;
    let method = masks::Method::parse(&args.str("method", "ccm-concat"))?;
    let policy = match method {
        masks::Method::CcmMerge => coordinator::session::SessionPolicy::merge(comp_len),
        _ => coordinator::session::SessionPolicy::concat(comp_len),
    };
    let shards = args.usize("shards", 1)?.max(1);
    let shard = args.usize("shard", 0)?;
    if shard >= shards {
        bail!("--shard {shard} out of range for --shards {shards}");
    }
    let mut cfg = server::ServerConfig::new(args.str("addr", "127.0.0.1:0"), policy);
    cfg.shards = shards;
    cfg.eviction = coordinator::session::EvictionKind::parse(&args.str("eviction", "oldest"))?;
    cfg.max_batch = args.usize("max-batch", 8)?;
    cfg.max_wait = std::time::Duration::from_millis(args.u64("max-wait-ms", 2)?);
    cfg.max_pending = args.usize("max-pending", 256)?;
    cfg.ipc_codec =
        server::IpcCodec::parse(&args.str_env("ipc-codec", "CCM_IPC_CODEC", "binary"))?;
    cfg.default_strategy = compress::StrategyKind::parse(&args.str("strategy", "ccm"))?;
    let tiers_spec = args.str("tiers", "");
    if !tiers_spec.is_empty() {
        cfg.tiers = compress::Tiers::parse(&tiers_spec)?;
    }
    let kv_budget_mb = args.usize("kv-budget-mb", 0)?;
    if kv_budget_mb > 0 {
        cfg.kv_budget_bytes = Some(kv_budget_mb * (1 << 20));
    }
    let ttl_secs = args.u64("session-ttl-secs", 0)?;
    if ttl_secs > 0 {
        cfg.session_ttl = Some(std::time::Duration::from_secs(ttl_secs));
    }
    let hibernate_dir = args.str("hibernate-dir", "");
    if !hibernate_dir.is_empty() {
        cfg.hibernate_dir = Some(std::path::PathBuf::from(&hibernate_dir));
    }
    let hibernate_after_secs = args.u64("hibernate-after-secs", 0)?;
    if hibernate_after_secs > 0 {
        cfg.hibernate_after = Some(std::time::Duration::from_secs(hibernate_after_secs));
    }
    cfg.orphan_grace = std::time::Duration::from_secs(
        args.u64("orphan-grace-secs", server::ORPHAN_GRACE_DEFAULT.as_secs())?,
    );
    let factory = serve_backend_factories(&config, &ckpt_path, seed, comp_len, 1)
        .pop()
        .expect("one worker factory");
    server::run_worker(&manifest, factory, cfg, shard, None)
}

fn load_or_init_checkpoint(
    manifest: &model::Manifest,
    ckpt_path: &str,
    seed: u64,
) -> Result<model::Checkpoint> {
    if ckpt_path.is_empty() {
        Ok(model::Checkpoint::init(manifest, seed))
    } else {
        model::Checkpoint::load(std::path::Path::new(ckpt_path), manifest)
    }
}

/// `ccm stream --stream-tokens 2048`
pub fn cli_stream(args: &Args) -> Result<()> {
    let config = args.str("config", "main");
    let budget = bench::Budget::from_args(args)?;
    let mut ctx = bench::ExpContext::new(&config, budget)?;
    bench::experiments::fig8_streaming(&mut ctx, args)
}

/// `ccm bench [--clients 8] [--rounds 120] [--emit BENCH_10.json]` —
/// serving-layer benchmark scenarios over the SimCompute backend (no
/// artifacts needed): in-process serve throughput, the 2-worker IPC
/// hop under BOTH `--ipc-codec` values (with the proxy's RTT p50/p99),
/// a wide-fan-in stress profile, and the pinned `loadgen-*` paper-
/// workload replays (`--loadgen-users`): the mixed population plus a
/// two-tier `dialog@ccm`/`dialog@none` split. `--emit PATH` writes the
/// machine-readable `BENCH_<n>.json` perf trajectory; `ccm bench
/// --compare OLD --against NEW` renders the markdown delta table CI
/// puts in its job summary (nonzero exit past the RTT p99 budget).
/// `--worker` is the internal re-exec entry the IPC scenarios spawn
/// their shard workers through.
pub fn cli_bench(args: &Args) -> Result<()> {
    bench::serving::run(args)
}

/// `ccm loadgen` — open-loop multi-tenant traffic replay of the
/// paper's workloads (conversation / LaMP / MetaICL / streaming)
/// against a running `ccm serve` instance over the real client
/// protocol, with per-scenario latency percentiles, a separate refusal
/// bucket, and sampled compression-quality scoring (ROUGE-L + peak-KV
/// accounting). Without `--addr` it self-serves a `--shards`-way
/// SimCompute server (`--strategy` sets its default compression
/// tier). `--scenario mixed|dialog|lamp|metaicl|stream` or an
/// explicit `--mix dialog=4,metaicl=2,...` picks the population; a
/// mix entry may pin a compression tier (`dialog@ccm=3,dialog@none=1`
/// — grammar `workload[@tier]=weight`), which splits that slice into
/// its own report row. `--emit PATH` writes the
/// `BENCH_<n>.json`-schema report. The
/// operator handbook mapping each paper evaluation to its loadgen
/// scenario is docs/SCENARIOS.md.
pub fn cli_loadgen(args: &Args) -> Result<()> {
    bench::loadgen::run(args)
}

/// `ccm reproduce --exp fig7|table1|...|all`
pub fn cli_reproduce(args: &Args) -> Result<()> {
    let exp = args.str("exp", "fig7");
    bench::run(&exp, args)
}
