"""Model + scenario configurations shared by the L1/L2 compile path.

Every static shape the AOT artifacts bake in lives here, and the whole
dict is exported into ``artifacts/<config>/manifest.json`` so the Rust
coordinator (L3) reads the exact same numbers — there is no other channel
between the compile path and the runtime.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer dimensions.

    The backbone mirrors a (scaled-down) LLaMA: RMSNorm, GELU MLP,
    learned absolute position embeddings (the paper's streaming mode
    reassigns position ids, which absolute embeddings support directly).
    """

    name: str = "main"
    vocab: int = 512
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 512
    max_pos: int = 512
    lora_rank: int = 8
    lora_alpha: float = 16.0
    # Reserved token ids (mirrored in rust/src/datagen/tokenizer.rs).
    pad_id: int = 0
    bos_id: int = 1
    sep_id: int = 2
    comp_id: int = 3

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class ScenarioConfig:
    """Static shapes of the online-inference scenario the artifacts bake in.

    ``seq_train`` must hold T_max chunks + their <COMP> tokens + the input
    segment: T_max * (chunk_max + comp_len_max) + input_max <= seq_train.

    The paper runs T=16 (MetaICL/LaMP) and T=12 (DailyDialog) on A100s;
    this CPU testbed scales the scenario to T=8 with proportionally
    shorter chunks — the method comparisons keep their shape (DESIGN.md).
    """

    t_max: int = 8             # max online time steps (paper: 12-16)
    chunk_max: int = 20        # max tokens per context chunk c(t)
    comp_len_max: int = 4      # max <COMP> tokens per chunk
    input_max: int = 32        # max tokens of I(t) (+ target O(t))
    seq_train: int = 224       # padded training sequence length
    mem_slots: int = 32        # merged-memory slots M (t_max * comp_len_max)
    batch_train: int = 8
    infer_batches: tuple = (1, 8)   # batch variants of serving artifacts
    decode_cache: int = 96     # KV-cache length for decode_step
    rmt_unroll: int = 4        # static unroll of the recurrent baseline
    rmt_mem: int = 4           # RMT summary-embedding slots

    def validate(self) -> None:
        need = self.t_max * (self.chunk_max + self.comp_len_max) + self.input_max
        assert need <= self.seq_train, (need, self.seq_train)
        assert self.mem_slots >= self.t_max * self.comp_len_max


@dataclass(frozen=True)
class Config:
    model: ModelConfig = field(default_factory=ModelConfig)
    scenario: ScenarioConfig = field(default_factory=ScenarioConfig)

    def to_dict(self) -> dict:
        d = {"model": asdict(self.model), "scenario": asdict(self.scenario)}
        d["model"]["d_head"] = self.model.d_head
        return d


def get_config(name: str) -> Config:
    """Named configs. ``test`` is for unit tests / CI; ``main`` is the
    headline config used by the end-to-end example and benches."""
    if name == "test":
        return Config(
            model=ModelConfig(
                name="test", vocab=256, d_model=64, n_layers=2, n_heads=2,
                d_ff=128, max_pos=256, lora_rank=4,
            ),
            scenario=ScenarioConfig(
                t_max=4, chunk_max=12, comp_len_max=2, input_max=16,
                seq_train=96, mem_slots=8, batch_train=4, infer_batches=(1, 4),
                decode_cache=48, rmt_unroll=2, rmt_mem=2,
            ),
        )
    if name == "main":
        return Config()
    if name == "big":
        # Scale ablation (Table 19 analogue): deeper + wider.
        return Config(
            model=ModelConfig(
                name="big", vocab=512, d_model=192, n_layers=6, n_heads=6,
                d_ff=768, max_pos=512, lora_rank=8,
            ),
            scenario=ScenarioConfig(),
        )
    if name == "wide":
        # Architecture ablation (Table 20 analogue): few wide heads.
        return Config(
            model=ModelConfig(
                name="wide", vocab=512, d_model=128, n_layers=4, n_heads=2,
                d_ff=768, max_pos=512, lora_rank=8,
            ),
            scenario=ScenarioConfig(),
        )
    raise ValueError(f"unknown config {name!r}")
