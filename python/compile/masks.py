"""Attention-mask + merge-matrix builders for the parallelized CCM forward.

This module is the *reference semantics* of the paper's Figure 3: the
recursive compression process

    h(t)   = g_comp(Mem(t-1), c(t))
    Mem(t) = g_update(Mem(t-1), h(t))

is unrolled into one forward pass over the packed sequence

    [ c(1), <COMP>*, c(2), <COMP>*, ..., c(T), <COMP>*, I(T) ]

by (a) a boolean attention mask over extended columns
``[M merged-memory slots | S token positions]`` and (b) a merge matrix
``P[M, S]`` that materialises Mem(j) as linear combinations of the KV at
<COMP> positions (CCM-merge) or raw chunk positions (Compressive
Transformer). One artifact + different (mask, P) inputs = every method.

Rust mirrors this file in ``rust/src/masks/``; ``aot.py`` exports golden
vectors into the manifest so the two implementations are cross-checked.
"""

from dataclasses import dataclass

import numpy as np

# Segment kinds (mirrored in rust/src/masks/layout.rs).
PAD, CHUNK, COMP, INPUT = 0, 1, 2, 3

METHODS = (
    "full",          # causal attention over the whole context (upper bound)
    "nocontext",     # input-only (lower bound)
    "ccm-concat",    # paper: scalable memory, Mem(t) = [h(1) ... h(t)]
    "ccm-merge",     # paper: fixed memory, Mem(t) = sum_j w_j h(j)
    "gist",          # Gisting-online baseline: per-chunk gist, no carryover
    "compressive",   # Compressive-Transformer baseline: pooled raw KV
)


@dataclass
class Layout:
    """Token-position layout of one packed training/eval sample."""

    kind: np.ndarray       # [S] int32, PAD/CHUNK/COMP/INPUT
    step: np.ndarray       # [S] int32, 1-based time step (0 for pad/input)
    comp_slot: np.ndarray  # [S] int32, 0 for non-comp, 1..comp_len for comp
    seq: int               # S
    t: int                 # number of chunks actually present
    comp_len: int          # <COMP> tokens per chunk (0 for full/compressive)
    chunk_lens: list       # actual chunk lengths
    input_len: int

    @property
    def n_tokens(self) -> int:
        return int(np.sum(self.kind != PAD))


def build_layout(chunk_lens, comp_len, input_len, seq):
    """Pack chunks (+ their <COMP> tokens) and the input segment into a
    sequence of static length ``seq``. No inter-segment padding; all the
    padding sits at the end, which keeps positions identical between the
    parallel forward and the recurrent online path."""
    kind = np.zeros(seq, dtype=np.int32)
    step = np.zeros(seq, dtype=np.int32)
    comp_slot = np.zeros(seq, dtype=np.int32)
    pos = 0
    for j, clen in enumerate(chunk_lens, start=1):
        assert pos + clen + comp_len <= seq, "layout overflow"
        kind[pos:pos + clen] = CHUNK
        step[pos:pos + clen] = j
        pos += clen
        if comp_len:
            kind[pos:pos + comp_len] = COMP
            step[pos:pos + comp_len] = j
            comp_slot[pos:pos + comp_len] = np.arange(1, comp_len + 1)
            pos += comp_len
    assert pos + input_len <= seq, "layout overflow (input)"
    kind[pos:pos + input_len] = INPUT
    pos += input_len
    return Layout(kind, step, comp_slot, seq, len(chunk_lens), comp_len,
                  list(chunk_lens), input_len)


def merge_weights(t, scheme):
    """Per-group merge coefficients w[g][j]: Mem(g) = sum_{j<=g} w[g][j] h(j).

    ``avg``    : arithmetic average, a_t = 1/t  (paper's main choice)
    ``ema:a``  : exponential moving average with constant a (a_1 = 1)
    """
    w = np.zeros((t + 1, t + 1), dtype=np.float64)
    if scheme == "avg":
        for g in range(1, t + 1):
            w[g, 1:g + 1] = 1.0 / g
    elif scheme.startswith("ema:"):
        a = float(scheme.split(":", 1)[1])
        assert 0.0 < a <= 1.0
        for g in range(1, t + 1):
            for j in range(1, g + 1):
                aj = 1.0 if j == 1 else a
                w[g, j] = aj * (1.0 - a) ** (g - j)
    else:
        raise ValueError(f"unknown merge scheme {scheme!r}")
    return w


def build_masks(method, lay: Layout, mem_slots, merge_scheme="avg", pool=None):
    """Return (mask[S, M+S] f32 in {0,1}, P[M, S] f32).

    Column order is [M memory-slot columns | S token columns]. The rules
    implement Section 3.1 of the paper: during training, c(j) and its
    <COMP> tokens may reference only Mem(j-1); I(t) references only Mem(t).

    ``pool`` is the Compressive-Transformer slot width per chunk (defaults
    to the layout's comp_len so all methods share one compression factor).
    """
    S, M, t, cl = lay.seq, mem_slots, lay.t, lay.comp_len
    pool = pool if pool is not None else max(cl, 1)
    mask = np.zeros((S, M + S), dtype=np.float32)
    P = np.zeros((M, S), dtype=np.float32)
    kind, step, slot = lay.kind, lay.step, lay.comp_slot
    idx = np.arange(S)

    def tok(col_pred):
        """Token-column selector -> column indices offset by M."""
        return M + idx[col_pred]

    def self_causal(i):
        """Same-segment causal columns for position i."""
        same = (kind == kind[i]) & (step == step[i]) & (idx <= i)
        return tok(same)

    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}")

    comp_cols_upto = {}   # j -> token columns of <COMP> tokens of chunks <= j
    if cl:
        for j in range(0, t + 1):
            comp_cols_upto[j] = tok((kind == COMP) & (step >= 1) & (step <= j))

    # --- merge matrix P ---------------------------------------------------
    if method == "ccm-merge":
        w = merge_weights(t, merge_scheme)
        for g in range(1, t + 1):
            for p in range(1, cl + 1):
                row = (g - 1) * cl + (p - 1)
                for j in range(1, g + 1):
                    src = idx[(kind == COMP) & (step == j) & (slot == p)]
                    assert len(src) == 1
                    P[row, src[0]] = w[g, j]
    elif method == "compressive":
        # Slot group g = chunk g mean-pooled into up-to-`pool` windows.
        assert t * pool <= M, (t, pool, M)
        for g in range(1, t + 1):
            src = idx[(kind == CHUNK) & (step == g)]
            windows = np.array_split(src, min(pool, len(src)))
            for p, wnd in enumerate(windows):
                row = (g - 1) * pool + p
                P[row, wnd] = 1.0 / len(wnd)

    def group_cols(g, width):
        return np.arange((g - 1) * width, g * width)

    # --- attention mask ----------------------------------------------------
    for i in range(S):
        k = kind[i]
        if k == PAD:
            mask[i, M + i] = 1.0   # inert but keeps softmax finite
            continue
        j = int(step[i])
        if method == "full":
            mask[i, tok((kind != PAD) & (idx <= i))] = 1.0
        elif method == "nocontext":
            if k == INPUT:
                mask[i, tok((kind == INPUT) & (idx <= i))] = 1.0
            else:
                mask[i, M + i] = 1.0
        elif method == "ccm-concat":
            mask[i, self_causal(i)] = 1.0
            if k == COMP:
                mask[i, tok((kind == CHUNK) & (step == j) & (idx <= i))] = 1.0
                mask[i, comp_cols_upto[j - 1]] = 1.0
            elif k == CHUNK:
                mask[i, comp_cols_upto[j - 1]] = 1.0
            else:  # INPUT attends Mem(T) = all <COMP> columns
                mask[i, comp_cols_upto[t]] = 1.0
        elif method == "ccm-merge":
            mask[i, self_causal(i)] = 1.0
            if k == COMP:
                mask[i, tok((kind == CHUNK) & (step == j) & (idx <= i))] = 1.0
                if j >= 2:
                    mask[i, group_cols(j - 1, cl)] = 1.0
            elif k == CHUNK:
                if j >= 2:
                    mask[i, group_cols(j - 1, cl)] = 1.0
            else:  # INPUT attends Mem(T)
                if t >= 1:
                    mask[i, group_cols(t, cl)] = 1.0
        elif method == "gist":
            mask[i, self_causal(i)] = 1.0
            if k == COMP:
                mask[i, tok((kind == CHUNK) & (step == j) & (idx <= i))] = 1.0
            elif k == INPUT:
                mask[i, comp_cols_upto[t]] = 1.0
        elif method == "compressive":
            # Only slots actually written by P (short chunks can fill
            # fewer than `pool` windows; zero-key slots must stay masked).
            live = P.sum(axis=1) > 0
            mask[i, self_causal(i)] = 1.0
            if k == CHUNK and j >= 2:
                for g in range(1, j):
                    cols = group_cols(g, pool)
                    mask[i, cols[live[cols]]] = 1.0
            elif k == INPUT:
                for g in range(1, t + 1):
                    cols = group_cols(g, pool)
                    mask[i, cols[live[cols]]] = 1.0
    return mask, P


def lora_gate(lay: Layout, conditional=True):
    """m[S] in {0,1}: where the conditional LoRA branch fires. The paper's
    conditional adapter gates on <COMP> tokens; the unconditional ablation
    (Table 5) fires everywhere."""
    if conditional:
        return (lay.kind == COMP).astype(np.float32)
    return (lay.kind != PAD).astype(np.float32)


def comp_slot_input(lay: Layout):
    """comp_slot[S] int32 fed to the model: 0 = normal token (use tok_emb),
    k>=1 = <COMP> slot k (use trainable comp_emb[k-1])."""
    return lay.comp_slot.astype(np.int32)


def position_ids(lay: Layout):
    """Absolute position ids: consecutive over the packed layout."""
    return np.arange(lay.seq, dtype=np.int32)


def loss_mask_for_target(lay: Layout, target_len):
    """1.0 on the last ``target_len`` INPUT positions (the O(t) tokens).
    The loss is next-token prediction, so the mask marks positions whose
    *next* token is a target token; the model helper shifts internally."""
    m = np.zeros(lay.seq, dtype=np.float32)
    inp = np.nonzero(lay.kind == INPUT)[0]
    assert target_len <= len(inp)
    if target_len:
        m[inp[-target_len:]] = 1.0
    return m
