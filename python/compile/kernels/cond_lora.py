"""L1 Pallas kernel: fused conditional-LoRA projection.

Implements the paper's conditional adapter (Section 3.1, Figure 4):

    y = x W + m · (x Aᵀ B) · (alpha / r)

where ``m = 1(token is <COMP>)``. Fusing the gate into the projection
avoids materialising the dense low-rank product for the ~95% of tokens
whose gate is zero; on TPU both matmuls are MXU-shaped and the gate is a
VPU broadcast within the tile. interpret=True on this testbed (see
ccm_attention.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cond_lora_kernel(x_ref, w_ref, a_ref, b_ref, gate_ref, o_ref, *, scale):
    """One row tile: x [bs, Di], w [Di, Do], a [r, Di], b [r, Do],
    gate [bs, 1] -> o [bs, Do]."""
    x = x_ref[...].astype(jnp.float32)
    base = x @ w_ref[...].astype(jnp.float32)            # MXU [bs, Do]
    low = (x @ a_ref[...].astype(jnp.float32).T)         # MXU [bs, r]
    low = low @ b_ref[...].astype(jnp.float32)           # MXU [bs, Do]
    o_ref[...] = base + gate_ref[...] * low * scale


def cond_lora(x, w, a, b, gate, scale, *, block_s=64, interpret=True):
    """x: [S, Di], w: [Di, Do], a: [r, Di], b: [r, Do], gate: [S] {0,1}.
    Returns [S, Do] f32."""
    s, di = x.shape
    do = w.shape[1]
    block_s = min(block_s, max(8, s))
    s_pad = -s % block_s
    if s_pad:
        x = jnp.pad(x, ((0, s_pad), (0, 0)))
        gate = jnp.pad(gate, (0, s_pad))
    sp = s + s_pad
    gate2 = gate.astype(jnp.float32)[:, None]

    kernel = functools.partial(_cond_lora_kernel, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(sp // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, di), lambda i: (i, 0)),
            pl.BlockSpec((di, do), lambda i: (0, 0)),
            pl.BlockSpec(a.shape, lambda i: (0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0, 0)),
            pl.BlockSpec((block_s, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, do), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, do), jnp.float32),
        interpret=interpret,
    )(x, w, a, b, gate2)
    return out[:s]
