"""L1 Pallas kernel: tiled masked attention over extended KV columns.

This is the compute hot-spot of the paper: every attention layer of the
parallelized CCM forward attends over columns ``[M memory slots | S token
positions]`` under the compression mask of Figure 3(b). The kernel is a
FlashAttention-style streaming-softmax kernel re-thought for the TPU
memory hierarchy (see DESIGN.md §3 Hardware adaptation):

* the grid tiles queries into (block_q, d_head) VMEM blocks;
* the KV stream is consumed in (block_k, d_head) tiles inside a
  ``fori_loop`` — the HBM→VMEM schedule a CUDA implementation would
  express with threadblocks is expressed here with BlockSpec + the loop;
* both matmuls (q·kᵀ and p·v) are MXU-shaped; mask logic is VPU
  elementwise within the tile;
* the CCM mask is block-sparse (a chunk attends its own band plus a few
  memory slots), so fully-masked KV tiles contribute exactly zero — the
  structure a real-TPU build would exploit by skipping grid steps.

MUST run with interpret=True on this testbed: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.

Perf note (EXPERIMENTS.md §Perf): default tiles are 128x128 — the
64x64 starting point used only 0.3% of a 16 MB VMEM budget; doubling
both axes raises the MXU-work fraction 0.948 -> 0.973 and quarters the
grid/loop step count, at 1.1% VMEM (double-buffering headroom intact).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _attention_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, block_k, scale):
    """One query tile: stream KV tiles with online softmax.

    q_ref: [block_q, dh], k_ref/v_ref: [C, dh], mask_ref: [block_q, C],
    o_ref: [block_q, dh].
    """
    block_q, dh = q_ref.shape
    c = k_ref.shape[0]
    q = q_ref[...].astype(jnp.float32) * scale

    def body(i, carry):
        m_prev, l_prev, acc = carry
        start = i * block_k
        k_tile = jax.lax.dynamic_slice(
            k_ref[...], (start, 0), (block_k, dh)).astype(jnp.float32)
        v_tile = jax.lax.dynamic_slice(
            v_ref[...], (start, 0), (block_k, dh)).astype(jnp.float32)
        m_tile = jax.lax.dynamic_slice(
            mask_ref[...], (0, start), (block_q, block_k))
        s = q @ k_tile.T                              # MXU: [bq, bk]
        s = jnp.where(m_tile > 0, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None]) * (m_tile > 0)  # VPU elementwise
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ v_tile   # MXU: [bq, dh]
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((block_q,), dtype=jnp.float32)
    acc0 = jnp.zeros((block_q, dh), dtype=jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, c // block_k, body, (m0, l0, acc0))
    o_ref[...] = acc / jnp.maximum(l, 1e-30)[:, None]


def ccm_attention(q, k, v, mask, *, block_q=128, block_k=128, interpret=True):
    """Tiled masked attention for one head.

    q: [S, dh], k/v: [C, dh] (C = mem_slots + S), mask: [S, C] in {0,1}.
    Returns [S, dh] f32. Pads S and C up to block multiples internally;
    padded columns are masked out, padded rows are sliced off.
    """
    s, dh = q.shape
    c = k.shape[0]
    block_q = min(block_q, max(8, s))
    block_k = min(block_k, max(8, c))
    s_pad = -s % block_q
    c_pad = -c % block_k
    if s_pad:
        q = jnp.pad(q, ((0, s_pad), (0, 0)))
        mask = jnp.pad(mask, ((0, s_pad), (0, 0)))
    if c_pad:
        k = jnp.pad(k, ((0, c_pad), (0, 0)))
        v = jnp.pad(v, ((0, c_pad), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, c_pad)))
    sp, cp = s + s_pad, c + c_pad

    kernel = functools.partial(
        _attention_kernel, block_k=block_k, scale=1.0 / (dh ** 0.5))
    out = pl.pallas_call(
        kernel,
        grid=(sp // block_q,),
        in_specs=[
            pl.BlockSpec((block_q, dh), lambda i: (i, 0)),
            pl.BlockSpec((cp, dh), lambda i: (0, 0)),
            pl.BlockSpec((cp, dh), lambda i: (0, 0)),
            pl.BlockSpec((block_q, cp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, dh), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp, dh), jnp.float32),
        interpret=interpret,
    )(q, k, v, mask)
    return out[:s]


def ccm_attention_batched(q, k, v, mask, **kw):
    """vmap over (batch, head): q [B, H, S, dh], k/v [B, H, C, dh],
    mask [B, S, C] (shared across heads)."""
    f = functools.partial(ccm_attention, **kw)
    per_head = jax.vmap(f, in_axes=(0, 0, 0, None))      # heads
    return jax.vmap(per_head, in_axes=(0, 0, 0, 0))(q, k, v, mask)
