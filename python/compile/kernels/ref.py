"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: ``python/tests/test_kernels.py``
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels
(interpret=True) match these to tight tolerances. They are also the
attention/projection path used inside differentiated (training) artifacts,
where the Pallas forward has no VJP.
"""

import jax.numpy as jnp

NEG_INF = -1e9


def ref_masked_attention(q, k, v, mask, scale=None):
    """Masked multi-column attention.

    q: [S, dh], k/v: [C, dh] (C = M + S extended columns),
    mask: [S, C] in {0,1}. Returns [S, dh] f32.
    """
    dh = q.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(dh)
    logits = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    logits = jnp.where(mask > 0, logits, NEG_INF)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs * (mask > 0)
    denom = probs.sum(axis=-1, keepdims=True)
    out = (probs / jnp.maximum(denom, 1e-30)) @ v.astype(jnp.float32)
    return out


def ref_cond_lora(x, w, a, b, gate, scale):
    """Conditional-LoRA projection: y = x W + gate * (x Aᵀ) B * scale.

    x: [S, Di], w: [Di, Do], a: [r, Di], b: [r, Do], gate: [S] in {0,1}.
    The gate implements m = 1(x = <COMP>) from Eq. (4) of the paper.
    """
    base = x @ w
    low = (x @ a.T) @ b
    return base + gate[:, None] * low * scale


def ref_merge_memory(p, k):
    """Merged-memory materialisation: slots = P @ K (per layer/head).

    p: [M, S], k: [S, dh] -> [M, dh].
    """
    return p @ k
