"""L2: the Transformer language model with Compressed Context Memory.

Everything here is build-time JAX: ``aot.py`` lowers these functions to
HLO text once, and the Rust coordinator executes the artifacts via PJRT.

Three forward flavours:

* ``forward_parallel``  — the paper's parallelized training/eval form
  (Figure 3): one packed sequence, attention mask + merge matrix P as
  runtime inputs, so a single artifact serves CCM-concat/-merge, Gisting,
  Compressive Transformer, full-context and no-context.
* ``forward_with_mem``  — the online serving form (Figure 5): attends to
  an external compressed-memory KV buffer; used by ``compress_chunk`` /
  ``infer_with_mem`` / ``decode_step``.
* ``forward_embeds``    — soft-embedding inputs, used by the recurrent
  (RMT/AutoCompressor-style) baseline.

The attention hot-spot can run through the L1 Pallas kernel
(``use_pallas=True``, inference artifacts) or the pure-jnp oracle
(training artifacts, which need a VJP).
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import params as P
from .config import Config
from .kernels.ccm_attention import ccm_attention_batched
from .kernels.ref import ref_masked_attention

NEG_INF = -1e9


# --------------------------------------------------------------------------
# Building blocks
# --------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-5):
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * scale


def cond_lora_proj(x, w, a, b, gate, scale):
    """Batched conditional-LoRA projection (jnp path; the Pallas kernel
    computes the identical expression for the serving artifacts).

    x: [B, S, D], gate: [B, S]."""
    base = x @ w
    low = (x @ a.T) @ b
    return base + gate[..., None] * low * scale


def split_heads(x, n_heads):
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def ref_attention_batched(q, k, v, mask):
    """vmapped oracle attention: q [B,H,S,dh], k/v [B,H,C,dh], mask [B,S,C]."""
    f = jax.vmap(ref_masked_attention, in_axes=(0, 0, 0, None))   # heads
    return jax.vmap(f, in_axes=(0, 0, 0, 0))(q, k, v, mask)


def embed(mp, lp, tokens, comp_slot, pos):
    """Token embedding with trainable <COMP> overrides.

    comp_slot == 0 -> frozen tok_emb[token]; slot k >= 1 -> comp_emb[k-1]
    (the jointly-optimised <COMP> embedding, shared across time steps).
    """
    tok = mp["tok_emb"][tokens]
    comp = lp["comp_emb"][jnp.maximum(comp_slot - 1, 0)]
    is_comp = (comp_slot > 0)[..., None]
    x = jnp.where(is_comp, comp, tok)
    return x + mp["pos_emb"][pos]


class LayerParams(NamedTuple):
    ln1: jax.Array
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    ln2: jax.Array
    w1: jax.Array
    w2: jax.Array


def layer_params(mp, i):
    p = f"layer{i}."
    return LayerParams(*(mp[p + k] for k in
                         ("ln1", "wq", "wk", "wv", "wo", "ln2", "w1", "w2")))


def lora_params(lp, i, proj):
    p = f"layer{i}."
    return lp[p + f"lora_{proj}_a"], lp[p + f"lora_{proj}_b"]


# --------------------------------------------------------------------------
# Parallel (training / eval) forward — Figure 3
# --------------------------------------------------------------------------

def forward_parallel(cfg: Config, base_vec, lora_vec, tokens, comp_slot,
                     gate, pos, mask, merge_p, use_pallas=False):
    """Packed-sequence forward with memory slots.

    tokens/comp_slot/gate/pos: [B, S]; mask: [B, S, M+S]; merge_p: [B, M, S].
    Returns logits [B, S, V] (f32).
    """
    m = cfg.model
    mp = P.unpack(base_vec, P.base_param_specs(m))
    lp = P.unpack(lora_vec, P.lora_param_specs(m, cfg.scenario.comp_len_max))
    scale = m.lora_alpha / m.lora_rank
    attn_fn = ccm_attention_batched if use_pallas else ref_attention_batched

    x = embed(mp, lp, tokens, comp_slot, pos)
    for i in range(m.n_layers):
        l = layer_params(mp, i)
        h = rmsnorm(x, l.ln1)
        q = cond_lora_proj(h, l.wq, *lora_params(lp, i, "q"), gate, scale)
        k = cond_lora_proj(h, l.wk, *lora_params(lp, i, "k"), gate, scale)
        v = cond_lora_proj(h, l.wv, *lora_params(lp, i, "v"), gate, scale)
        # Memory slots: Mem(j) materialised as linear combinations of this
        # layer's KV at <COMP> (or pooled chunk) positions — Eq. (2).
        mem_k = merge_p @ k                                   # [B, M, D]
        mem_v = merge_p @ v
        qh = split_heads(q, m.n_heads)
        kh = split_heads(jnp.concatenate([mem_k, k], axis=1), m.n_heads)
        vh = split_heads(jnp.concatenate([mem_v, v], axis=1), m.n_heads)
        o = attn_fn(qh, kh, vh, mask)
        o = cond_lora_proj(merge_heads(o), l.wo,
                           *lora_params(lp, i, "o"), gate, scale)
        x = x + o
        h2 = rmsnorm(x, l.ln2)
        x = x + jax.nn.gelu(h2 @ l.w1) @ l.w2
    x = rmsnorm(x, mp["final_norm"])
    return x @ mp["lm_head"]


# --------------------------------------------------------------------------
# Online serving forward — Figure 5 (external compressed memory)
# --------------------------------------------------------------------------

def forward_with_mem(cfg: Config, base_vec, lora_vec, mem_k, mem_v, mem_len,
                     tokens, comp_slot, gate, pos, use_pallas=False,
                     collect_kv=False):
    """Short-sequence forward attending to compressed memory.

    mem_k/mem_v: [B, L, M_max, D] per-layer, per-sample memory KV with
    valid prefix mem_len[B]. tokens: [B, S].
    Returns (logits, per-layer (k, v) of the sequence) — callers slice the
    <COMP> positions out of the KV to produce h(t).
    """
    m = cfg.model
    mp = P.unpack(base_vec, P.base_param_specs(m))
    lp = P.unpack(lora_vec, P.lora_param_specs(m, cfg.scenario.comp_len_max))
    scale = m.lora_alpha / m.lora_rank
    attn_fn = ccm_attention_batched if use_pallas else ref_attention_batched

    b, s = tokens.shape
    m_max = mem_k.shape[2]
    # Column validity: memory prefix + non-pad tokens; rows causal.
    col_mem = (jnp.arange(m_max)[None, :] < mem_len[:, None])      # [B, M]
    tok_valid = tokens != m.pad_id                                 # [B, S]
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    mask_tok = causal[None] & tok_valid[:, None, :]
    mask = jnp.concatenate(
        [jnp.broadcast_to(col_mem[:, None, :], (b, s, m_max)), mask_tok],
        axis=2).astype(jnp.float32)
    # Guarantee self-attention so padded rows stay finite.
    eye = jnp.eye(s, dtype=jnp.float32)
    mask = mask.at[:, :, m_max:].set(jnp.maximum(mask[:, :, m_max:], eye))

    x = embed(mp, lp, tokens, comp_slot, pos)
    kvs = []
    for i in range(m.n_layers):
        l = layer_params(mp, i)
        h = rmsnorm(x, l.ln1)
        q = cond_lora_proj(h, l.wq, *lora_params(lp, i, "q"), gate, scale)
        k = cond_lora_proj(h, l.wk, *lora_params(lp, i, "k"), gate, scale)
        v = cond_lora_proj(h, l.wv, *lora_params(lp, i, "v"), gate, scale)
        if collect_kv:
            kvs.append((k, v))
        qh = split_heads(q, m.n_heads)
        kh = split_heads(jnp.concatenate([mem_k[:, i], k], axis=1), m.n_heads)
        vh = split_heads(jnp.concatenate([mem_v[:, i], v], axis=1), m.n_heads)
        o = attn_fn(qh, kh, vh, mask)
        o = cond_lora_proj(merge_heads(o), l.wo,
                           *lora_params(lp, i, "o"), gate, scale)
        x = x + o
        h2 = rmsnorm(x, l.ln2)
        x = x + jax.nn.gelu(h2 @ l.w1) @ l.w2
    x = rmsnorm(x, mp["final_norm"])
    return x @ mp["lm_head"], kvs


# --------------------------------------------------------------------------
# Soft-embedding forward — recurrent (RMT-style) baseline
# --------------------------------------------------------------------------

def forward_embeds(cfg: Config, base_vec, lora_vec, embeds, valid, pos,
                   gate=None):
    """Causal forward over soft embeddings (unconditional LoRA active).

    embeds: [B, S, D] already includes any summary-slot embeddings;
    valid: [B, S] float 0/1. Returns (logits, final hidden states).
    """
    m = cfg.model
    mp = P.unpack(base_vec, P.base_param_specs(m))
    lp = P.unpack(lora_vec, P.lora_param_specs(m, cfg.scenario.comp_len_max))
    scale = m.lora_alpha / m.lora_rank
    b, s, _ = embeds.shape
    if gate is None:
        gate = valid  # unconditional: adapter fires on every real token
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    mask = (causal[None] & (valid[:, None, :] > 0)).astype(jnp.float32)
    eye = jnp.eye(s, dtype=jnp.float32)
    mask = jnp.maximum(mask, eye[None])

    x = embeds + mp["pos_emb"][pos]
    for i in range(m.n_layers):
        l = layer_params(mp, i)
        h = rmsnorm(x, l.ln1)
        q = cond_lora_proj(h, l.wq, *lora_params(lp, i, "q"), gate, scale)
        k = cond_lora_proj(h, l.wk, *lora_params(lp, i, "k"), gate, scale)
        v = cond_lora_proj(h, l.wv, *lora_params(lp, i, "v"), gate, scale)
        qh, kh, vh = (split_heads(t, m.n_heads) for t in (q, k, v))
        o = ref_attention_batched(qh, kh, vh, mask)
        o = cond_lora_proj(merge_heads(o), l.wo,
                           *lora_params(lp, i, "o"), gate, scale)
        x = x + o
        h2 = rmsnorm(x, l.ln2)
        x = x + jax.nn.gelu(h2 @ l.w1) @ l.w2
    hidden = x
    x = rmsnorm(x, mp["final_norm"])
    return x @ mp["lm_head"], hidden


# --------------------------------------------------------------------------
# Single-token decode with KV cache (autoregressive generation)
# --------------------------------------------------------------------------

def decode_step(cfg: Config, base_vec, lora_vec, mem_k, mem_v, mem_len,
                cache_k, cache_v, cache_len, token, pos):
    """One decode step: attends compressed memory + KV cache, appends the
    new token's KV at ``cache_len``. token/pos: [B]; cache_k/v:
    [B, L, Cc, D]; mem_k/v: [B, L, Mm, D]; cache_len scalar i32.
    Returns (logits [B, V], cache_k', cache_v')."""
    m = cfg.model
    mp = P.unpack(base_vec, P.base_param_specs(m))
    lp = P.unpack(lora_vec, P.lora_param_specs(m, cfg.scenario.comp_len_max))
    scale = m.lora_alpha / m.lora_rank
    b = token.shape[0]
    m_max, cc = mem_k.shape[2], cache_k.shape[2]
    x = mp["tok_emb"][token][:, None] + mp["pos_emb"][pos][:, None]
    gate = jnp.zeros((b, 1), dtype=jnp.float32)
    col_mem = jnp.arange(m_max)[None, :] < mem_len[:, None]
    col_cache = jnp.broadcast_to(
        (jnp.arange(cc)[None, :] <= cache_len), (b, cc))
    mask = jnp.concatenate([col_mem, col_cache], axis=1) \
        .astype(jnp.float32)[:, None, :]                  # [B, 1, Mm+Cc]
    new_ck, new_cv = [], []
    for i in range(m.n_layers):
        l = layer_params(mp, i)
        h = rmsnorm(x, l.ln1)
        q = cond_lora_proj(h, l.wq, *lora_params(lp, i, "q"), gate, scale)
        k = cond_lora_proj(h, l.wk, *lora_params(lp, i, "k"), gate, scale)
        v = cond_lora_proj(h, l.wv, *lora_params(lp, i, "v"), gate, scale)
        ck = jax.lax.dynamic_update_slice(cache_k[:, i], k, (0, cache_len, 0))
        cv = jax.lax.dynamic_update_slice(cache_v[:, i], v, (0, cache_len, 0))
        new_ck.append(ck)
        new_cv.append(cv)
        qh = split_heads(q, m.n_heads)
        kh = split_heads(jnp.concatenate([mem_k[:, i], ck], axis=1), m.n_heads)
        vh = split_heads(jnp.concatenate([mem_v[:, i], cv], axis=1), m.n_heads)
        o = ref_attention_batched(qh, kh, vh, mask)
        o = cond_lora_proj(merge_heads(o), l.wo,
                           *lora_params(lp, i, "o"), gate, scale)
        x = x + o
        h2 = rmsnorm(x, l.ln2)
        x = x + jax.nn.gelu(h2 @ l.w1) @ l.w2
    x = rmsnorm(x, mp["final_norm"])
    logits = (x @ mp["lm_head"])[:, 0]
    return logits, jnp.stack(new_ck, axis=1), jnp.stack(new_cv, axis=1)


# --------------------------------------------------------------------------
# Losses + optimiser (Adam carried through the artifact)
# --------------------------------------------------------------------------

def next_token_loss(logits, tokens, loss_mask):
    """Mean CE over positions i with loss_mask[i]=1, predicting token i+1."""
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    w = loss_mask[:, :-1]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def adam_update(grad, param, mu, nu, step, lr,
                b1=0.9, b2=0.999, eps=1e-8, clip=1.0):
    """Single flat-vector Adam step with global-norm clipping."""
    gnorm = jnp.sqrt(jnp.sum(jnp.square(grad)) + 1e-12)
    grad = grad * jnp.minimum(1.0, clip / gnorm)
    mu = b1 * mu + (1 - b1) * grad
    nu = b2 * nu + (1 - b2) * jnp.square(grad)
    t = step.astype(jnp.float32) + 1.0
    mhat = mu / (1 - b1 ** t)
    nhat = nu / (1 - b2 ** t)
    param = param - lr * mhat / (jnp.sqrt(nhat) + eps)
    return param, mu, nu


def train_lm_step(cfg: Config, base_vec, mu, nu, step, lr, tokens, pos,
                  loss_mask):
    """Full-weight LM pretraining step (causal attention, no compression)."""
    b, s = tokens.shape
    m_slots = 1  # dummy memory column, masked off

    def loss_fn(bv):
        zeros = jnp.zeros((b, s), dtype=jnp.int32)
        gate = jnp.zeros((b, s), dtype=jnp.float32)
        causal = jnp.tril(jnp.ones((s, s), dtype=bool))
        valid = tokens != cfg.model.pad_id
        mask_tok = (causal[None] & valid[:, None, :]).astype(jnp.float32)
        eye = jnp.eye(s, dtype=jnp.float32)[None]
        mask_tok = jnp.maximum(mask_tok, eye)
        mask = jnp.concatenate(
            [jnp.zeros((b, s, m_slots), jnp.float32), mask_tok], axis=2)
        merge_p = jnp.zeros((b, m_slots, s), dtype=jnp.float32)
        lora_dummy = jnp.zeros((P.lora_size(cfg),), dtype=jnp.float32)
        logits = forward_parallel(cfg, bv, lora_dummy, tokens, zeros, gate,
                                  pos, mask, merge_p)
        return next_token_loss(logits, tokens, loss_mask)

    loss, grad = jax.value_and_grad(loss_fn)(base_vec)
    base_vec, mu, nu = adam_update(grad, base_vec, mu, nu, step, lr)
    return base_vec, mu, nu, loss


def train_ccm_step(cfg: Config, base_vec, lora_vec, mu, nu, step, lr,
                   tokens, comp_slot, gate, pos, mask, merge_p, loss_mask):
    """Compression-training step: Eq. (4) — only the conditional-LoRA +
    <COMP>-embedding vector is trainable; the base model is frozen."""

    def loss_fn(lv):
        logits = forward_parallel(cfg, base_vec, lv, tokens, comp_slot,
                                  gate, pos, mask, merge_p)
        return next_token_loss(logits, tokens, loss_mask)

    loss, grad = jax.value_and_grad(loss_fn)(lora_vec)
    lora_vec, mu, nu = adam_update(grad, lora_vec, mu, nu, step, lr)
    return lora_vec, mu, nu, loss


def train_rmt_step(cfg: Config, base_vec, lora_vec, mu, nu, step, lr,
                   chunks, chunk_valid, inputs, input_valid, loss_mask):
    """Recurrent-compression (RMT/AutoCompressor-style) training step.

    The recursion over time steps is *sequential* — this is exactly the
    training-cost structure Table 8 measures against CCM's single parallel
    forward. chunks: [B, R, Sc] tokens; inputs: [B, Si].
    """
    m, sc = cfg.model, cfg.scenario
    b, r, s_c = chunks.shape
    n_mem = sc.rmt_mem

    def loss_fn(lv):
        lp = P.unpack(lv, P.lora_param_specs(m, sc.comp_len_max))
        mem = jnp.broadcast_to(lp["comp_emb"][:n_mem][None],
                               (b, n_mem, m.d_model))
        mp = P.unpack(base_vec, P.base_param_specs(m))
        for j in range(r):
            toks = chunks[:, j]
            emb = mp["tok_emb"][toks]
            x = jnp.concatenate([emb, mem], axis=1)    # summary slots last
            valid = jnp.concatenate(
                [chunk_valid[:, j], jnp.ones((b, n_mem))], axis=1)
            pos = jnp.broadcast_to(
                jnp.arange(s_c + n_mem, dtype=jnp.int32)[None],
                (b, s_c + n_mem))
            _, hidden = forward_embeds(cfg, base_vec, lv, x, valid, pos)
            mem = hidden[:, -n_mem:]                   # h(t) -> Mem(t)
        emb_in = mp["tok_emb"][inputs]
        x = jnp.concatenate([mem, emb_in], axis=1)
        valid = jnp.concatenate([jnp.ones((b, n_mem)), input_valid], axis=1)
        si = inputs.shape[1]
        pos = jnp.broadcast_to(
            jnp.arange(n_mem + si, dtype=jnp.int32)[None], (b, n_mem + si))
        logits, _ = forward_embeds(cfg, base_vec, lv, x, valid, pos)
        logits = logits[:, n_mem:]
        return next_token_loss(logits, inputs, loss_mask)

    loss, grad = jax.value_and_grad(loss_fn)(lora_vec)
    lora_vec, mu, nu = adam_update(grad, lora_vec, mu, nu, step, lr)
    return lora_vec, mu, nu, loss
