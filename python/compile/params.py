"""Parameter layout: flat f32 vectors <-> named tensors.

Both the frozen base parameters and the trainable compression parameters
(conditional LoRA + <COMP> embeddings) travel between Rust and the XLA
artifacts as single 1-D f32 buffers. This module defines the canonical
layout; the offsets are exported to ``manifest.json`` and mirrored by
``rust/src/model/layout.rs``. All slicing below is static, so XLA folds
the unpacking away.
"""

import math

import jax.numpy as jnp

from .config import Config, ModelConfig


def base_param_specs(m: ModelConfig):
    """Ordered (name, shape) list for the base model parameter vector."""
    specs = [
        ("tok_emb", (m.vocab, m.d_model)),
        ("pos_emb", (m.max_pos, m.d_model)),
        ("final_norm", (m.d_model,)),
        ("lm_head", (m.d_model, m.vocab)),
    ]
    for i in range(m.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1", (m.d_model,)),
            (p + "wq", (m.d_model, m.d_model)),
            (p + "wk", (m.d_model, m.d_model)),
            (p + "wv", (m.d_model, m.d_model)),
            (p + "wo", (m.d_model, m.d_model)),
            (p + "ln2", (m.d_model,)),
            (p + "w1", (m.d_model, m.d_ff)),
            (p + "w2", (m.d_ff, m.d_model)),
        ]
    return specs


def lora_param_specs(m: ModelConfig, comp_len_max: int):
    """Ordered (name, shape) list for the trainable compression vector:
    conditional-LoRA A/B for q,k,v,o of every layer + <COMP> embeddings."""
    specs = [("comp_emb", (comp_len_max, m.d_model))]
    for i in range(m.n_layers):
        p = f"layer{i}."
        for proj in ("q", "k", "v", "o"):
            specs += [
                (p + f"lora_{proj}_a", (m.lora_rank, m.d_model)),
                (p + f"lora_{proj}_b", (m.lora_rank, m.d_model)),
            ]
    return specs


def layout(specs):
    """(name, shape) list -> [(name, offset, size, shape)], total."""
    out, off = [], 0
    for name, shape in specs:
        size = math.prod(shape)
        out.append((name, off, size, shape))
        off += size
    return out, off


def unpack(vec, specs):
    """Flat vector -> {name: tensor} via static slices."""
    lay, total = layout(specs)
    assert vec.shape[-1] == total, (vec.shape, total)
    return {
        name: jnp.reshape(vec[off:off + size], shape)
        for name, off, size, shape in lay
    }


def base_size(cfg: Config) -> int:
    return layout(base_param_specs(cfg.model))[1]


def lora_size(cfg: Config) -> int:
    return layout(lora_param_specs(cfg.model, cfg.scenario.comp_len_max))[1]


def layout_manifest(cfg: Config) -> dict:
    """Layout description exported to manifest.json for the Rust side."""
    def describe(specs):
        lay, total = layout(specs)
        return {
            "total": total,
            "entries": [
                {"name": n, "offset": o, "size": s, "shape": list(sh)}
                for n, o, s, sh in lay
            ],
        }
    return {
        "base": describe(base_param_specs(cfg.model)),
        "lora": describe(lora_param_specs(cfg.model, cfg.scenario.comp_len_max)),
    }
