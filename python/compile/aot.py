"""AOT export: lower every L2 graph to HLO text + write manifest.json.

Python runs exactly once (``make artifacts``); afterwards the Rust binary
is self-contained. Interchange format is HLO **text**, not serialized
HloModuleProto — jax >= 0.5 emits protos with 64-bit instruction ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

The manifest is the single source of truth the Rust side reads: model +
scenario config, flat parameter layouts, per-artifact I/O signatures, and
golden mask/merge vectors used to cross-check the Rust mask builders
against python/compile/masks.py.
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import masks as MK
from . import model as M
from . import params as P
from .config import Config, get_config

jax.config.update("jax_platform_name", "cpu")

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


# --------------------------------------------------------------------------
# Artifact definitions
# --------------------------------------------------------------------------

def artifact_defs(cfg: Config):
    """[(name, fn, [(arg_name, spec)])] for every artifact of one config."""
    m, sc = cfg.model, cfg.scenario
    B, S = sc.batch_train, sc.seq_train
    Mm = sc.mem_slots
    D, L, V = m.d_model, m.n_layers, m.vocab
    nb, nl = P.base_size(cfg), P.lora_size(cfg)
    Sc, cl, Si = sc.chunk_max, sc.comp_len_max, sc.input_max
    Scc = Sc + cl                      # compress_chunk sequence length
    R, nm = sc.rmt_unroll, sc.rmt_mem
    Se = max(Scc + nm, nm + Si)        # RMT forward sequence length
    Cc = sc.decode_cache

    defs = []

    defs.append((
        "train_lm_step",
        functools.partial(M.train_lm_step, cfg),
        [("base", spec([nb])), ("mu", spec([nb])), ("nu", spec([nb])),
         ("step", spec([], I32)), ("lr", spec([])),
         ("tokens", spec([B, S], I32)), ("pos", spec([B, S], I32)),
         ("loss_mask", spec([B, S]))],
    ))

    defs.append((
        "train_ccm_step",
        functools.partial(M.train_ccm_step, cfg),
        [("base", spec([nb])), ("lora", spec([nl])),
         ("mu", spec([nl])), ("nu", spec([nl])),
         ("step", spec([], I32)), ("lr", spec([])),
         ("tokens", spec([B, S], I32)), ("comp_slot", spec([B, S], I32)),
         ("gate", spec([B, S])), ("pos", spec([B, S], I32)),
         ("mask", spec([B, S, Mm + S])), ("merge_p", spec([B, Mm, S])),
         ("loss_mask", spec([B, S]))],
    ))

    defs.append((
        "train_rmt_step",
        functools.partial(M.train_rmt_step, cfg),
        [("base", spec([nb])), ("lora", spec([nl])),
         ("mu", spec([nl])), ("nu", spec([nl])),
         ("step", spec([], I32)), ("lr", spec([])),
         ("chunks", spec([B, R, Sc], I32)), ("chunk_valid", spec([B, R, Sc])),
         ("inputs", spec([B, Si], I32)), ("input_valid", spec([B, Si])),
         ("loss_mask", spec([B, Si]))],
    ))

    def ccm_forward(use_pallas, base, lora, tokens, comp_slot, gate, pos,
                    mask, merge_p):
        return (M.forward_parallel(cfg, base, lora, tokens, comp_slot, gate,
                                   pos, mask, merge_p, use_pallas=use_pallas),)

    for b in sc.infer_batches:
        defs.append((
            f"ccm_forward_b{b}",
            functools.partial(ccm_forward, False),
            [("base", spec([nb])), ("lora", spec([nl])),
             ("tokens", spec([b, S], I32)), ("comp_slot", spec([b, S], I32)),
             ("gate", spec([b, S])), ("pos", spec([b, S], I32)),
             ("mask", spec([b, S, Mm + S])), ("merge_p", spec([b, Mm, S]))],
        ))
    defs.append((
        "ccm_forward_pallas_b1",
        functools.partial(ccm_forward, True),
        [("base", spec([nb])), ("lora", spec([nl])),
         ("tokens", spec([1, S], I32)), ("comp_slot", spec([1, S], I32)),
         ("gate", spec([1, S])), ("pos", spec([1, S], I32)),
         ("mask", spec([1, S, Mm + S])), ("merge_p", spec([1, Mm, S]))],
    ))

    def compress_chunk(base, lora, mem_k, mem_v, mem_len, tokens, comp_slot,
                       gate, pos):
        _, kvs = M.forward_with_mem(cfg, base, lora, mem_k, mem_v, mem_len,
                                    tokens, comp_slot, gate, pos,
                                    collect_kv=True)
        # h(t): KV at the <COMP> positions (statically the last cl slots).
        hk = jnp.stack([k[:, Sc:Scc] for k, _ in kvs], axis=1)  # [B,L,cl,D]
        hv = jnp.stack([v[:, Sc:Scc] for _, v in kvs], axis=1)
        return hk, hv

    def infer_with_mem(base, lora, mem_k, mem_v, mem_len, tokens, pos):
        b, s = tokens.shape
        zeros = jnp.zeros((b, s), dtype=I32)
        gate = jnp.zeros((b, s), dtype=F32)
        logits, _ = M.forward_with_mem(cfg, base, lora, mem_k, mem_v,
                                       mem_len, tokens, zeros, gate, pos)
        return (logits,)

    for b in sc.infer_batches:
        defs.append((
            f"compress_chunk_b{b}",
            compress_chunk,
            [("base", spec([nb])), ("lora", spec([nl])),
             ("mem_k", spec([b, L, Mm, D])), ("mem_v", spec([b, L, Mm, D])),
             ("mem_len", spec([b], I32)),
             ("tokens", spec([b, Scc], I32)),
             ("comp_slot", spec([b, Scc], I32)),
             ("gate", spec([b, Scc])), ("pos", spec([b, Scc], I32))],
        ))
        defs.append((
            f"infer_with_mem_b{b}",
            infer_with_mem,
            [("base", spec([nb])), ("lora", spec([nl])),
             ("mem_k", spec([b, L, Mm, D])), ("mem_v", spec([b, L, Mm, D])),
             ("mem_len", spec([b], I32)),
             ("tokens", spec([b, Si], I32)), ("pos", spec([b, Si], I32))],
        ))

    defs.append((
        "decode_step",
        functools.partial(M.decode_step, cfg),
        [("base", spec([nb])), ("lora", spec([nl])),
         ("mem_k", spec([1, L, Mm, D])), ("mem_v", spec([1, L, Mm, D])),
         ("mem_len", spec([1], I32)),
         ("cache_k", spec([1, L, Cc, D])), ("cache_v", spec([1, L, Cc, D])),
         ("cache_len", spec([], I32)),
         ("token", spec([1], I32)), ("pos", spec([1], I32))],
    ))

    def rmt_forward(base, lora, embeds, valid, pos):
        logits, hidden = M.forward_embeds(cfg, base, lora, embeds, valid, pos)
        return logits, hidden

    for b in sc.infer_batches:
        defs.append((
            f"rmt_forward_b{b}",
            rmt_forward,
            [("base", spec([nb])), ("lora", spec([nl])),
             ("embeds", spec([b, Se, D])), ("valid", spec([b, Se])),
             ("pos", spec([b, Se], I32))],
        ))

    return defs


# --------------------------------------------------------------------------
# Golden vectors for the Rust mask builder
# --------------------------------------------------------------------------

def mask_goldens(cfg: Config):
    """Small layouts x all methods, serialized compactly. Rust rebuilds the
    same masks and must match bit-for-bit."""
    sc = cfg.scenario
    cases = []
    scenarios = [
        ([5, 4, 6], 2, 8, 48, 8),
        ([3, 3], 1, 6, 24, 4),
        ([7], 2, 10, 24, 4),
    ]
    for chunk_lens, comp_len, input_len, seq, mem in scenarios:
        for method in MK.METHODS:
            cl = 0 if method in ("full", "compressive") else comp_len
            chunks = [] if method == "nocontext" else chunk_lens
            lay = MK.build_layout(chunks, cl, input_len, seq)
            for scheme in (["avg", "ema:0.5"] if method == "ccm-merge"
                           else ["avg"]):
                mask, p = MK.build_masks(method, lay, mem, scheme,
                                         pool=comp_len)
                cases.append({
                    "method": method,
                    "scheme": scheme,
                    "chunk_lens": chunks,
                    "comp_len": cl,
                    "pool": comp_len,
                    "input_len": input_len,
                    "seq": seq,
                    "mem_slots": mem,
                    "kind": lay.kind.tolist(),
                    "step": lay.step.tolist(),
                    "comp_slot": lay.comp_slot.tolist(),
                    "mask_rows": ["".join("1" if x > 0 else "0" for x in row)
                                  for row in mask],
                    "p_nonzero": [[int(r), int(c), float(p[r, c])]
                                  for r, c in zip(*np.nonzero(p))],
                })
    return cases


# --------------------------------------------------------------------------
# Main
# --------------------------------------------------------------------------

def lower_all(cfg: Config, out_dir: str, only=None):
    os.makedirs(out_dir, exist_ok=True)
    arts = []
    for name, fn, args in artifact_defs(cfg):
        if only and name not in only:
            continue
        specs = [s for _, s in args]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        outs = [{"dtype": str(o.dtype), "shape": list(o.shape)}
                for o in jax.tree_util.tree_leaves(out_avals)]
        arts.append({
            "name": name,
            "file": f"{name}.hlo.txt",
            "inputs": [{"name": n, "dtype": str(s.dtype),
                        "shape": list(s.shape)} for n, s in args],
            "outputs": outs,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        })
        print(f"  lowered {name}: {len(text)/1e6:.2f} MB")
    return arts


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="main")
    ap.add_argument("--out", default=None,
                    help="output dir (default ../artifacts/<config>)")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of artifact names")
    args = ap.parse_args()

    cfg = get_config(args.config)
    cfg.scenario.validate()
    out_dir = args.out or os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", args.config)
    out_dir = os.path.abspath(out_dir)
    print(f"[aot] config={args.config} -> {out_dir}")

    arts = lower_all(cfg, out_dir, only=args.only)
    manifest = {
        "config_name": args.config,
        "config": cfg.to_dict(),
        "params": P.layout_manifest(cfg),
        "artifacts": arts,
        "mask_goldens": mask_goldens(cfg),
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    print(f"[aot] wrote manifest with {len(arts)} artifacts, "
          f"{len(manifest['mask_goldens'])} mask goldens")


if __name__ == "__main__":
    main()
