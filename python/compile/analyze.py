"""Performance analysis of the compile-path (L1/L2) — DESIGN.md §8.

interpret=True Pallas gives CPU-numpy timings that are NOT a TPU proxy,
so L1 is analysed structurally: VMEM footprint and MXU-utilisation
estimates from the BlockSpecs. L2 is analysed with XLA's cost analysis on
the compiled module (FLOPs, bytes accessed, output bytes) and fusion
counts from the optimized HLO.

Usage: python -m compile.analyze --config main
"""

import argparse

import jax
import jax.numpy as jnp

from . import masks  # noqa: F401  (import keeps the package rooted)
from .aot import artifact_defs
from .config import get_config

jax.config.update("jax_platform_name", "cpu")


def l1_vmem_report(cfg, block_q=128, block_k=128):
    """VMEM footprint + MXU-work fraction of the attention kernel tile.

    Per grid step the kernel holds: Q tile (bq x dh), one K/V tile
    (bk x dh each), the mask tile (bq x bk), softmax stats (2 x bq) and
    the accumulator (bq x dh) — all f32.
    """
    m = cfg.model
    sc = cfg.scenario
    dh = m.d_head
    c = sc.mem_slots + sc.seq_train
    bq, bk = min(block_q, sc.seq_train), min(block_k, c)
    tile_floats = bq * dh + 2 * bk * dh + bq * bk + bq * dh + 2 * bq
    vmem_bytes = tile_floats * 4
    # MXU vs VPU work per tile: two matmuls (q@kT: bq*bk*dh, p@v: bq*bk*dh
    # MACs) vs elementwise mask/softmax (~5*bq*bk flops).
    mxu_flops = 2 * (bq * bk * dh) * 2
    vpu_flops = 5 * bq * bk + 4 * bq * dh
    frac = mxu_flops / (mxu_flops + vpu_flops)
    return {
        "block_q": bq,
        "block_k": bk,
        "d_head": dh,
        "kv_cols": c,
        "vmem_per_step_bytes": vmem_bytes,
        "vmem_budget_frac": vmem_bytes / (16 * 2**20),
        "mxu_work_fraction": frac,
        "grid_steps": -(-sc.seq_train // bq),
        "kv_tiles_per_step": -(-c // bk),
    }


def l2_cost_report(cfg, names=("ccm_forward_b1", "train_ccm_step")):
    """Compile selected artifacts and read XLA's cost analysis."""
    defs = {n: (fn, args) for n, fn, args in artifact_defs(cfg)}
    out = {}
    for name in names:
        fn, args = defs[name]
        specs = [s for _, s in args]
        compiled = jax.jit(fn).lower(*specs).compile()
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
        except Exception:  # pragma: no cover - backend-dependent
            ca = {}
        hlo = compiled.as_text()
        out[name] = {
            "flops": ca.get("flops", float("nan")),
            "bytes_accessed": ca.get("bytes accessed", float("nan")),
            "fusions": hlo.count(" fusion("),
            "convolutions_or_dots": hlo.count(" dot("),
            "while_loops": hlo.count(" while("),
            "hlo_lines": hlo.count("\n"),
        }
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--config", default="main")
    ap.add_argument("--block-q", type=int, default=128)
    ap.add_argument("--block-k", type=int, default=128)
    args = ap.parse_args()
    cfg = get_config(args.config)

    print(f"== L1 Pallas attention kernel — VMEM/MXU estimate ({args.config}) ==")
    rep = l1_vmem_report(cfg, args.block_q, args.block_k)
    for k, v in rep.items():
        print(f"  {k:24} {v:.4f}" if isinstance(v, float) else f"  {k:24} {v}")

    print(f"\n== L2 XLA cost analysis ({args.config}) ==")
    for name, stats in l2_cost_report(cfg).items():
        print(f"  {name}:")
        for k, v in stats.items():
            print(f"    {k:20} {v:,.0f}" if isinstance(v, float) else f"    {k:20} {v}")


if __name__ == "__main__":
    main()
