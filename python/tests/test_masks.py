"""Semantics of the parallelized-training masks (paper Figure 3).

These properties pin down the information-flow rules of Section 3.1:
c(j) and its <COMP> tokens may reference only Mem(j-1); I(t) references
only Mem(t); merge weights realise the g_update recurrences.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import masks as MK


def rand_scenario(rng, max_chunks=5):
    t = int(rng.integers(1, max_chunks + 1))
    chunk_lens = [int(rng.integers(2, 9)) for _ in range(t)]
    comp_len = int(rng.integers(1, 4))
    input_len = int(rng.integers(2, 10))
    seq = sum(chunk_lens) + t * comp_len + input_len + int(rng.integers(0, 6))
    mem = t * comp_len
    return chunk_lens, comp_len, input_len, seq, mem


@settings(deadline=None, max_examples=40)
@given(seed=st.integers(0, 2**31 - 1),
       method=st.sampled_from(["ccm-concat", "ccm-merge", "gist"]))
def test_information_flow_rules(seed, method):
    rng = np.random.default_rng(seed)
    chunk_lens, cl, il, seq, mem = rand_scenario(rng)
    lay = MK.build_layout(chunk_lens, cl, il, seq)
    mask, p = MK.build_masks(method, lay, mem)
    M = mem
    kind, step, idx = lay.kind, lay.step, np.arange(seq)
    t = lay.t

    for i in range(seq):
        row = mask[i]
        allowed_tok = np.nonzero(row[M:])[0]
        allowed_mem = np.nonzero(row[:M])[0]
        if kind[i] == MK.PAD:
            assert list(allowed_tok) == [i] and len(allowed_mem) == 0
            continue
        # Never attend the future or pad columns.
        assert all(kind[c] != MK.PAD or c == i for c in allowed_tok)
        assert all(c <= i for c in allowed_tok)
        j = step[i]
        if kind[i] == MK.CHUNK:
            # Raw tokens of OTHER chunks are never visible (the whole point
            # of compression: previous context only through Mem(j-1)).
            assert all(not (kind[c] == MK.CHUNK and step[c] != j)
                       for c in allowed_tok)
            if method == "ccm-concat":
                comp_prev = set(idx[(kind == MK.COMP) & (step < j)])
                assert set(allowed_tok) - set(idx[(kind == MK.CHUNK)
                                                  & (step == j)]) == comp_prev
                assert len(allowed_mem) == 0
            elif method == "ccm-merge":
                want = set(range((j - 2) * cl, (j - 1) * cl)) if j >= 2 else set()
                assert set(allowed_mem) == want
            elif method == "gist":
                assert len(allowed_mem) == 0
                assert all(step[c] == j for c in allowed_tok)
        elif kind[i] == MK.COMP:
            # <COMP> sees its chunk + Mem(j-1) (gist: chunk only).
            assert all(step[c] == j or kind[c] == MK.COMP
                       for c in allowed_tok)
            if method == "gist":
                assert all(step[c] == j for c in allowed_tok)
        elif kind[i] == MK.INPUT:
            # I(t) accesses context ONLY through Mem(t) (Eq. 3).
            assert all(kind[c] == MK.INPUT or kind[c] == MK.COMP
                       for c in allowed_tok)
            if method in ("ccm-concat", "gist"):
                comp_all = set(idx[kind == MK.COMP])
                assert comp_all <= set(allowed_tok)
                assert len(allowed_mem) == 0
            elif method == "ccm-merge":
                assert set(allowed_mem) == set(range((t - 1) * cl, t * cl))
                assert all(kind[c] == MK.INPUT for c in allowed_tok)


@settings(deadline=None, max_examples=30)
@given(seed=st.integers(0, 2**31 - 1))
def test_full_is_causal_and_nocontext_is_input_only(seed):
    rng = np.random.default_rng(seed)
    chunk_lens, _, il, seq, mem = rand_scenario(rng)
    lay = MK.build_layout(chunk_lens, 0, il, seq)
    mask, _ = MK.build_masks("full", lay, mem)
    kind, idx = lay.kind, np.arange(seq)
    for i in range(seq):
        if kind[i] == MK.PAD:
            continue
        want = set(idx[(kind != MK.PAD) & (idx <= i)])
        assert set(np.nonzero(mask[i][mem:])[0]) == want
        assert mask[i][:mem].sum() == 0

    lay2 = MK.build_layout([], 0, il, seq)
    mask2, _ = MK.build_masks("nocontext", lay2, mem)
    for i in range(seq):
        if lay2.kind[i] != MK.INPUT:
            continue
        cols = set(np.nonzero(mask2[i][mem:])[0])
        assert cols == set(idx[(lay2.kind == MK.INPUT) & (idx <= i)])


@settings(deadline=None, max_examples=30)
@given(t=st.integers(1, 10), seed=st.integers(0, 1000))
def test_merge_weights_avg_recurrence(t, seed):
    """Arithmetic average == the recurrence Mem(t)=(1-1/t)Mem(t-1)+h(t)/t."""
    w = MK.merge_weights(t, "avg")
    rng = np.random.default_rng(seed)
    h = rng.standard_normal((t + 1, 7))
    mem = np.zeros(7)
    for g in range(1, t + 1):
        a = 1.0 / g
        mem = (1 - a) * mem + a * h[g]
        closed = sum(w[g, j] * h[j] for j in range(1, g + 1))
        np.testing.assert_allclose(mem, closed, rtol=1e-10, atol=1e-12)
        np.testing.assert_allclose(w[g, 1:g + 1].sum(), 1.0, rtol=1e-12)


@settings(deadline=None, max_examples=30)
@given(t=st.integers(1, 10), a=st.floats(0.05, 1.0), seed=st.integers(0, 1000))
def test_merge_weights_ema_recurrence(t, a, seed):
    w = MK.merge_weights(t, f"ema:{a}")
    rng = np.random.default_rng(seed)
    h = rng.standard_normal((t + 1, 5))
    mem = np.zeros(5)
    for g in range(1, t + 1):
        ag = 1.0 if g == 1 else a
        mem = (1 - ag) * mem + ag * h[g]
        closed = sum(w[g, j] * h[j] for j in range(1, g + 1))
        np.testing.assert_allclose(mem, closed, rtol=1e-9, atol=1e-12)


def test_merge_p_materialises_weights():
    lay = MK.build_layout([4, 3, 5], 2, 6, 40)
    _, p = MK.build_masks("ccm-merge", lay, 8)
    w = MK.merge_weights(3, "avg")
    comp_pos = {(j, s): int(np.nonzero((lay.kind == MK.COMP)
                                       & (lay.step == j)
                                       & (lay.comp_slot == s))[0][0])
                for j in (1, 2, 3) for s in (1, 2)}
    for g in (1, 2, 3):
        for s in (1, 2):
            row = p[(g - 1) * 2 + (s - 1)]
            for j in (1, 2, 3):
                want = w[g, j] if j <= g else 0.0
                np.testing.assert_allclose(row[comp_pos[(j, s)]], want,
                                           rtol=1e-6)


def test_compressive_pooling_sums_to_one():
    lay = MK.build_layout([6, 5], 0, 6, 32)
    mask, p = MK.build_masks("compressive", lay, 8, pool=2)
    live = p.sum(axis=1) > 0
    np.testing.assert_allclose(p[live].sum(axis=1), 1.0, rtol=1e-6)
    # Input attends exactly the live slots.
    inp = np.nonzero(lay.kind == MK.INPUT)[0][0]
    assert set(np.nonzero(mask[inp][:8])[0]) == set(np.nonzero(live)[0])
    # Chunk 2 attends only chunk-1 slots.
    c2 = np.nonzero((lay.kind == MK.CHUNK) & (lay.step == 2))[0][0]
    assert set(np.nonzero(mask[c2][:8])[0]) <= set(range(2))


def test_layout_packing_and_helpers():
    lay = MK.build_layout([3, 4], 2, 5, 24)
    assert lay.n_tokens == 3 + 2 + 4 + 2 + 5
    np.testing.assert_array_equal(
        lay.kind[:16],
        [MK.CHUNK] * 3 + [MK.COMP] * 2 + [MK.CHUNK] * 4 + [MK.COMP] * 2
        + [MK.INPUT] * 5)
    gate = MK.lora_gate(lay)
    assert gate.sum() == 4 and (gate[lay.kind == MK.COMP] == 1).all()
    gate_u = MK.lora_gate(lay, conditional=False)
    assert gate_u.sum() == lay.n_tokens
    lm = MK.loss_mask_for_target(lay, 3)
    assert lm.sum() == 3 and (np.nonzero(lm)[0] == [13, 14, 15]).all()
    with pytest.raises(AssertionError):
        MK.build_layout([20], 2, 10, 24)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
