"""Parameter layout + AOT export invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import params as P
from compile.config import get_config

jax.config.update("jax_platform_name", "cpu")


def test_layout_is_dense_and_ordered():
    cfg = get_config("test")
    for specs in (P.base_param_specs(cfg.model),
                  P.lora_param_specs(cfg.model, cfg.scenario.comp_len_max)):
        lay, total = P.layout(specs)
        off = 0
        for name, offset, size, shape in lay:
            assert offset == off, name
            assert size == int(np.prod(shape))
            off += size
        assert off == total


def test_unpack_roundtrip():
    cfg = get_config("test")
    specs = P.base_param_specs(cfg.model)
    _, total = P.layout(specs)
    vec = jnp.arange(total, dtype=jnp.float32)
    d = P.unpack(vec, specs)
    # Every element lands exactly once.
    flat = jnp.concatenate([d[n].reshape(-1) for n, _ in specs])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(vec))
    # Shapes match the spec.
    for name, shape in specs:
        assert d[name].shape == shape


def test_lora_layout_has_all_projections():
    cfg = get_config("test")
    names = [n for n, _ in P.lora_param_specs(cfg.model, 2)]
    assert names[0] == "comp_emb"
    for i in range(cfg.model.n_layers):
        for proj in ("q", "k", "v", "o"):
            assert f"layer{i}.lora_{proj}_a" in names
            assert f"layer{i}.lora_{proj}_b" in names


def test_artifact_defs_cover_contract():
    """The Rust runtime expects these artifacts with these arities."""
    cfg = get_config("test")
    defs = {name: args for name, _, args in aot.artifact_defs(cfg)}
    expect = {
        "train_lm_step": 8,
        "train_ccm_step": 13,
        "train_rmt_step": 11,
        "ccm_forward_b1": 8,
        "ccm_forward_pallas_b1": 8,
        "compress_chunk_b1": 9,
        "infer_with_mem_b1": 7,
        "decode_step": 10,
        "rmt_forward_b1": 5,
    }
    for name, arity in expect.items():
        assert name in defs, name
        assert len(defs[name]) == arity, name
    # Batch variants exist for every serving artifact.
    for b in cfg.scenario.infer_batches:
        for base in ("ccm_forward", "compress_chunk", "infer_with_mem", "rmt_forward"):
            assert f"{base}_b{b}" in defs


def test_mask_goldens_are_self_consistent():
    cfg = get_config("test")
    goldens = aot.mask_goldens(cfg)
    methods = {g["method"] for g in goldens}
    assert methods == {"full", "nocontext", "ccm-concat", "ccm-merge",
                       "gist", "compressive"}
    for g in goldens:
        assert len(g["mask_rows"]) == g["seq"]
        for row in g["mask_rows"]:
            assert len(row) == g["mem_slots"] + g["seq"]
            assert set(row) <= {"0", "1"}
        for r, c, v in g["p_nonzero"]:
            assert 0 <= r < g["mem_slots"]
            assert 0 <= c < g["seq"]
            assert 0 < v <= 1.0 + 1e-6
        # EMA goldens only for merge.
        if g["scheme"].startswith("ema"):
            assert g["method"] == "ccm-merge"


def test_hlo_text_lowering_smoke():
    """The HLO-text interchange path works for a minimal function."""
    def fn(x):
        return (x @ x.T + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[4,4]" in text


def test_scenario_validation_catches_overflow():
    cfg = get_config("test")
    cfg.scenario.validate()  # fine
    from compile.config import Config, ModelConfig, ScenarioConfig
    bad = Config(model=ModelConfig(), scenario=ScenarioConfig(
        t_max=100, chunk_max=24, comp_len_max=4, input_max=32,
        seq_train=64, mem_slots=400))
    with pytest.raises(AssertionError):
        bad.scenario.validate()


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
