"""L2 model correctness.

The centrepiece is the parallel<->recurrent equivalence test: the paper's
parallelized training forward (Figure 3) must produce exactly the logits
of the online recursion (Figures 2/5) — compress chunk-by-chunk, update
Mem(t) by concat or merge, then infer with the memory. This is the claim
that makes single-forward training of a recursive system sound.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import masks as MK
from compile import model as M
from compile import params as P
from compile.config import get_config

jax.config.update("jax_platform_name", "cpu")

CFG = get_config("test")


def rand_params(seed=0):
    rng = np.random.default_rng(seed)
    base = (rng.standard_normal(P.base_size(CFG)) * 0.05).astype(np.float32)
    lora = (rng.standard_normal(P.lora_size(CFG)) * 0.05).astype(np.float32)
    return jnp.asarray(base), jnp.asarray(lora)


def rand_tokens(rng, n):
    return rng.integers(4, CFG.model.vocab, size=n, dtype=np.int32)


def build_sample(rng, t=3, comp_len=2, input_len=8, seq=None):
    seq = seq or CFG.scenario.seq_train
    chunk_lens = [int(rng.integers(4, CFG.scenario.chunk_max - 2))
                  for _ in range(t)]
    lay = MK.build_layout(chunk_lens, comp_len, input_len, seq)
    tokens = np.zeros(seq, dtype=np.int32)
    pos = 0
    for clen in chunk_lens:
        tokens[pos:pos + clen] = rand_tokens(rng, clen)
        pos += clen
        tokens[pos:pos + comp_len] = CFG.model.comp_id
        pos += comp_len
    tokens[pos:pos + input_len] = rand_tokens(rng, input_len)
    return lay, tokens


def parallel_logits(method, lay, tokens, base, lora, scheme="avg"):
    sc = CFG.scenario
    mask, p = MK.build_masks(method, lay, sc.mem_slots, scheme)
    logits = M.forward_parallel(
        CFG, base, lora,
        jnp.asarray(tokens)[None],
        jnp.asarray(MK.comp_slot_input(lay))[None],
        jnp.asarray(MK.lora_gate(lay))[None],
        jnp.asarray(MK.position_ids(lay))[None],
        jnp.asarray(mask)[None],
        jnp.asarray(p)[None])
    return np.asarray(logits[0])


def recurrent_logits(method, lay, tokens, base, lora, ema=None):
    """Simulate the online path: compress each chunk with forward_with_mem,
    update memory (concat or merge), infer the input with the memory."""
    m, sc = CFG.model, CFG.scenario
    L, D, Mm = m.n_layers, m.d_model, sc.mem_slots
    cl = lay.comp_len
    mem_k = np.zeros((1, L, Mm, D), dtype=np.float32)
    mem_v = np.zeros((1, L, Mm, D), dtype=np.float32)
    mem_len = 0
    start = 0
    for j, clen in enumerate(lay.chunk_lens, start=1):
        buf = sc.chunk_max + sc.comp_len_max
        toks = np.zeros(buf, dtype=np.int32)
        slots = np.zeros(buf, dtype=np.int32)
        gate = np.zeros(buf, dtype=np.float32)
        posv = np.zeros(buf, dtype=np.int32)
        toks[:clen] = tokens[start:start + clen]
        posv[:clen] = np.arange(start, start + clen)
        cstart = sc.chunk_max
        toks[cstart:cstart + cl] = m.comp_id
        slots[cstart:cstart + cl] = np.arange(1, cl + 1)
        gate[cstart:cstart + cl] = 1.0
        posv[cstart:cstart + cl] = np.arange(start + clen, start + clen + cl)
        _, kvs = M.forward_with_mem(
            CFG, base, lora, jnp.asarray(mem_k), jnp.asarray(mem_v),
            jnp.asarray([mem_len], dtype=jnp.int32),
            jnp.asarray(toks)[None], jnp.asarray(slots)[None],
            jnp.asarray(gate)[None], jnp.asarray(posv)[None],
            collect_kv=True)
        hk = np.stack([np.asarray(k[0, cstart:cstart + cl]) for k, _ in kvs])
        hv = np.stack([np.asarray(v[0, cstart:cstart + cl]) for _, v in kvs])
        if method == "ccm-concat":
            mem_k[0, :, mem_len:mem_len + cl] = hk
            mem_v[0, :, mem_len:mem_len + cl] = hv
            mem_len += cl
        else:  # ccm-merge
            a = (1.0 if j == 1 else ema) if ema is not None else 1.0 / j
            mem_k[0, :, :cl] = (1 - a) * mem_k[0, :, :cl] + a * hk
            mem_v[0, :, :cl] = (1 - a) * mem_v[0, :, :cl] + a * hv
            mem_len = cl
        start += clen + cl

    il = lay.input_len
    toks = np.zeros(CFG.scenario.input_max, dtype=np.int32)
    toks[:il] = tokens[start:start + il]
    posv = np.zeros(CFG.scenario.input_max, dtype=np.int32)
    posv[:il] = np.arange(start, start + il)
    zeros = np.zeros(CFG.scenario.input_max, dtype=np.int32)
    gate = np.zeros(CFG.scenario.input_max, dtype=np.float32)
    logits, _ = M.forward_with_mem(
        CFG, base, lora, jnp.asarray(mem_k), jnp.asarray(mem_v),
        jnp.asarray([mem_len], dtype=jnp.int32),
        jnp.asarray(toks)[None], jnp.asarray(zeros)[None],
        jnp.asarray(gate)[None], jnp.asarray(posv)[None])
    return np.asarray(logits[0, :il]), start


@pytest.mark.parametrize("method", ["ccm-concat", "ccm-merge"])
def test_parallel_equals_recurrent(method):
    rng = np.random.default_rng(7)
    base, lora = rand_params(1)
    lay, tokens = build_sample(rng, t=3, comp_len=2, input_len=8)
    par = parallel_logits(method, lay, tokens, base, lora)
    rec, start = recurrent_logits(method, lay, tokens, base, lora)
    inp = np.nonzero(lay.kind == MK.INPUT)[0]
    np.testing.assert_allclose(par[inp], rec, rtol=5e-4, atol=5e-4)


def test_parallel_equals_recurrent_ema():
    rng = np.random.default_rng(8)
    base, lora = rand_params(2)
    lay, tokens = build_sample(rng, t=4, comp_len=2, input_len=6)
    par = parallel_logits("ccm-merge", lay, tokens, base, lora,
                          scheme="ema:0.5")
    rec, _ = recurrent_logits("ccm-merge", lay, tokens, base, lora, ema=0.5)
    inp = np.nonzero(lay.kind == MK.INPUT)[0]
    np.testing.assert_allclose(par[inp], rec, rtol=5e-4, atol=5e-4)


def test_conditional_gate_isolates_lora():
    """With the conditional gate all-zero, the LoRA vector must not change
    the logits at all — the paper's guarantee that compression parameters
    leave the base model intact on normal tokens."""
    rng = np.random.default_rng(9)
    base, lora = rand_params(3)
    lay, tokens = build_sample(rng, t=0, comp_len=0, input_len=10)
    mask, p = MK.build_masks("nocontext", lay, CFG.scenario.mem_slots)
    zeros_slot = jnp.zeros((1, lay.seq), dtype=jnp.int32)
    gate0 = jnp.zeros((1, lay.seq), dtype=jnp.float32)
    args = (jnp.asarray(tokens)[None], zeros_slot, gate0,
            jnp.asarray(MK.position_ids(lay))[None],
            jnp.asarray(mask)[None], jnp.asarray(p)[None])
    l1 = M.forward_parallel(CFG, base, lora, *args)
    l2 = M.forward_parallel(CFG, base, jnp.zeros_like(lora), *args)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-6, atol=1e-6)


def test_pallas_forward_matches_ref_forward():
    rng = np.random.default_rng(10)
    base, lora = rand_params(4)
    lay, tokens = build_sample(rng, t=2, comp_len=2, input_len=8)
    ref = parallel_logits("ccm-concat", lay, tokens, base, lora)
    sc = CFG.scenario
    mask, p = MK.build_masks("ccm-concat", lay, sc.mem_slots)
    pal = M.forward_parallel(
        CFG, base, lora, jnp.asarray(tokens)[None],
        jnp.asarray(MK.comp_slot_input(lay))[None],
        jnp.asarray(MK.lora_gate(lay))[None],
        jnp.asarray(MK.position_ids(lay))[None],
        jnp.asarray(mask)[None], jnp.asarray(p)[None], use_pallas=True)
    np.testing.assert_allclose(np.asarray(pal[0]), ref, rtol=2e-4, atol=2e-4)


def test_decode_step_matches_infer_with_mem():
    rng = np.random.default_rng(11)
    base, lora = rand_params(5)
    m, sc = CFG.model, CFG.scenario
    L, D, Mm, Cc = m.n_layers, m.d_model, sc.mem_slots, sc.decode_cache
    mem_k = jnp.asarray(rng.standard_normal((1, L, Mm, D)) * 0.1,
                        dtype=jnp.float32)
    mem_v = jnp.asarray(rng.standard_normal((1, L, Mm, D)) * 0.1,
                        dtype=jnp.float32)
    mem_len = jnp.asarray([3], dtype=jnp.int32)
    n = 9
    toks = rand_tokens(rng, n)

    # Reference: batch scoring with infer_with_mem.
    buf = np.zeros(sc.input_max, dtype=np.int32)
    buf[:n] = toks
    posv = np.zeros(sc.input_max, dtype=np.int32)
    posv[:n] = np.arange(n)
    zeros = np.zeros(sc.input_max, dtype=np.int32)
    gate = np.zeros(sc.input_max, dtype=np.float32)
    ref_logits, _ = M.forward_with_mem(
        CFG, base, lora, mem_k, mem_v, mem_len,
        jnp.asarray(buf)[None], jnp.asarray(zeros)[None],
        jnp.asarray(gate)[None], jnp.asarray(posv)[None])
    ref_logits = np.asarray(ref_logits[0, :n])

    # Decode token-by-token.
    cache_k = jnp.zeros((1, L, Cc, D), dtype=jnp.float32)
    cache_v = jnp.zeros((1, L, Cc, D), dtype=jnp.float32)
    got = []
    for i, tk in enumerate(toks):
        logits, cache_k, cache_v = M.decode_step(
            CFG, base, lora, mem_k, mem_v, mem_len, cache_k, cache_v,
            jnp.asarray(i, dtype=jnp.int32),
            jnp.asarray([tk], dtype=jnp.int32),
            jnp.asarray([i], dtype=jnp.int32))
        got.append(np.asarray(logits[0]))
    np.testing.assert_allclose(np.stack(got), ref_logits,
                               rtol=5e-4, atol=5e-4)


def test_train_steps_decrease_loss():
    rng = np.random.default_rng(12)
    base, lora = rand_params(6)
    sc = CFG.scenario
    B, S = sc.batch_train, sc.seq_train
    toks = np.zeros((B, S), dtype=np.int32)
    slot = np.zeros((B, S), dtype=np.int32)
    gate = np.zeros((B, S), dtype=np.float32)
    posv = np.zeros((B, S), dtype=np.int32)
    maskb = np.zeros((B, S, sc.mem_slots + S), dtype=np.float32)
    pb = np.zeros((B, sc.mem_slots, S), dtype=np.float32)
    lossb = np.zeros((B, S), dtype=np.float32)
    for b in range(B):
        lay, tk = build_sample(rng, t=2, comp_len=2, input_len=8)
        mask, p = MK.build_masks("ccm-concat", lay, sc.mem_slots)
        toks[b], maskb[b], pb[b] = tk, mask, p
        slot[b] = MK.comp_slot_input(lay)
        gate[b] = MK.lora_gate(lay)
        posv[b] = MK.position_ids(lay)
        lossb[b] = MK.loss_mask_for_target(lay, 4)
    mu = jnp.zeros_like(lora)
    nu = jnp.zeros_like(lora)
    args = tuple(jnp.asarray(x) for x in (toks, slot, gate, posv, maskb, pb,
                                          lossb))
    step_fn = jax.jit(lambda lv, mu, nu, s: M.train_ccm_step(
        CFG, base, lv, mu, nu, s, jnp.float32(1e-2), *args))
    losses = []
    lv = lora
    for s in range(8):
        lv, mu, nu, loss = step_fn(lv, mu, nu, jnp.asarray(s, jnp.int32))
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
