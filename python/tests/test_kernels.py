"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

Hypothesis sweeps shapes, block sizes and mask patterns; every property
asserts allclose against ref.py. This is the core correctness signal for
the kernels that end up inside every AOT artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ccm_attention import ccm_attention, ccm_attention_batched
from compile.kernels.cond_lora import cond_lora
from compile.kernels.ref import (
    ref_cond_lora,
    ref_masked_attention,
    ref_merge_memory,
)

jax.config.update("jax_platform_name", "cpu")


def rand_mask(rng, s, c, density):
    """Random mask with at least one allowed column per row (the model
    guarantees self-attention, so all-masked rows never occur)."""
    m = (rng.random((s, c)) < density).astype(np.float32)
    for i in range(s):
        if m[i].sum() == 0:
            m[i, rng.integers(0, c)] = 1.0
    return m


@settings(deadline=None, max_examples=25)
@given(
    s=st.integers(1, 40),
    extra=st.integers(0, 24),
    dh=st.sampled_from([4, 8, 16, 32]),
    density=st.floats(0.05, 1.0),
    block_q=st.sampled_from([8, 16, 64]),
    block_k=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(s, extra, dh, density, block_q, block_k, seed):
    rng = np.random.default_rng(seed)
    c = s + extra
    q = rng.standard_normal((s, dh), dtype=np.float32)
    k = rng.standard_normal((c, dh), dtype=np.float32)
    v = rng.standard_normal((c, dh), dtype=np.float32)
    mask = rand_mask(rng, s, c, density)
    got = ccm_attention(q, k, v, mask, block_q=block_q, block_k=block_k)
    want = ref_masked_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_attention_fully_masked_row_is_finite():
    # Defensive: even a pathological all-masked row must not emit NaN.
    s, c, dh = 4, 8, 8
    q = np.ones((s, dh), dtype=np.float32)
    k = np.ones((c, dh), dtype=np.float32)
    v = np.ones((c, dh), dtype=np.float32)
    mask = np.zeros((s, c), dtype=np.float32)
    out = np.asarray(ccm_attention(q, k, v, mask))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, 0.0)


def test_attention_masked_columns_have_no_influence():
    rng = np.random.default_rng(0)
    s, c, dh = 12, 20, 8
    q = rng.standard_normal((s, dh), dtype=np.float32)
    k = rng.standard_normal((c, dh), dtype=np.float32)
    v = rng.standard_normal((c, dh), dtype=np.float32)
    mask = rand_mask(rng, s, c, 0.4)
    out1 = np.asarray(ccm_attention(q, k, v, mask))
    # Scrambling masked K/V entries must not change the output.
    k2, v2 = k.copy(), v.copy()
    for col in range(c):
        if mask[:, col].sum() == 0:
            k2[col] = 1e3
            v2[col] = -1e3
    out2 = np.asarray(ccm_attention(q, k2, v2, mask))
    np.testing.assert_allclose(out1, out2, rtol=1e-6, atol=1e-6)


def test_attention_batched_matches_per_head_loop():
    rng = np.random.default_rng(1)
    b, h, s, c, dh = 2, 3, 10, 16, 8
    q = rng.standard_normal((b, h, s, dh), dtype=np.float32)
    k = rng.standard_normal((b, h, c, dh), dtype=np.float32)
    v = rng.standard_normal((b, h, c, dh), dtype=np.float32)
    mask = np.stack([rand_mask(rng, s, c, 0.5) for _ in range(b)])
    got = np.asarray(ccm_attention_batched(q, k, v, mask))
    for bi in range(b):
        for hi in range(h):
            want = ref_masked_attention(q[bi, hi], k[bi, hi], v[bi, hi],
                                        mask[bi])
            np.testing.assert_allclose(got[bi, hi], np.asarray(want),
                                       rtol=2e-5, atol=2e-5)


@settings(deadline=None, max_examples=25)
@given(
    s=st.integers(1, 48),
    di=st.sampled_from([8, 16, 32]),
    do=st.sampled_from([8, 16, 32]),
    r=st.sampled_from([2, 4, 8]),
    block_s=st.sampled_from([8, 32, 64]),
    cond=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_cond_lora_matches_ref(s, di, do, r, block_s, cond, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((s, di), dtype=np.float32)
    w = rng.standard_normal((di, do), dtype=np.float32)
    a = rng.standard_normal((r, di), dtype=np.float32)
    b = rng.standard_normal((r, do), dtype=np.float32)
    gate = (rng.random(s) < 0.3).astype(np.float32) if cond \
        else np.ones(s, dtype=np.float32)
    scale = 16.0 / r
    got = cond_lora(x, w, a, b, gate, scale, block_s=block_s)
    want = ref_cond_lora(x, w, a, b, gate, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_cond_lora_zero_gate_is_pure_base():
    rng = np.random.default_rng(2)
    x = rng.standard_normal((10, 16), dtype=np.float32)
    w = rng.standard_normal((16, 16), dtype=np.float32)
    a = rng.standard_normal((4, 16), dtype=np.float32)
    b = rng.standard_normal((4, 16), dtype=np.float32)
    gate = np.zeros(10, dtype=np.float32)
    got = np.asarray(cond_lora(x, w, a, b, gate, 4.0))
    np.testing.assert_allclose(got, x @ w, rtol=1e-5, atol=1e-5)


def test_merge_memory_ref_is_linear():
    rng = np.random.default_rng(3)
    p = rng.standard_normal((6, 20)).astype(np.float32)
    k = rng.standard_normal((20, 8)).astype(np.float32)
    out = np.asarray(ref_merge_memory(jnp.asarray(p), jnp.asarray(k)))
    np.testing.assert_allclose(out, p @ k, rtol=1e-5, atol=1e-5)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
