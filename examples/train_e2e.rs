//! END-TO-END driver: the full paper pipeline on a real (synthetic)
//! workload, proving all three layers compose.
//!
//!   1. Pretrain the base transformer LM on the synthetic corpus,
//!      logging the loss curve (the "dataset fine-tune" of Appendix B).
//!   2. Train the conditional-LoRA compression adapter with the
//!      parallelized CCM forward (Algorithm 1) for concat AND merge.
//!   3. Evaluate accuracy over online time steps against no-context and
//!      full-context, reporting the paper-style comparison + KV memory.
//!
//! Defaults to the `main` config (~10 min on CPU); `--config test
//! --steps-lm 60 --steps 30 --eval-n 15` finishes in ~2 min. Results are
//! recorded in EXPERIMENTS.md §End-to-end.
//!
//!   cargo run --release --example train_e2e [-- --config main]

use anyhow::Result;
use ccm::bench::{AdapterSpec, Budget, ExpContext};
use ccm::datagen::by_name;
use ccm::eval::Evaluator;
use ccm::masks::Method;
use ccm::training::pack::PackPolicy;
use ccm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let config = args.str("config", "main");
    let budget = Budget::from_args(&args)?;
    let mut ctx = ExpContext::new(&config, budget)?;
    let mixture = args.str("mixture", "metaicl");
    let dataset = args.str("dataset", "metaicl");
    let comp_len = args.usize("comp-len", 2)?;

    println!("== CCM end-to-end: pretrain -> compression train -> online eval ==");
    println!(
        "config {config}: {} base params, {} adapter params",
        ctx.manifest().base_layout.total,
        ctx.manifest().lora_layout.total
    );

    // Phase 1+2 (cached if already trained): loss curves logged by the
    // trainer; the checkpoint cache makes reruns instant.
    let t0 = std::time::Instant::now();
    let _base = ctx.base(&mixture)?;
    println!("[phase 1] base LM ready ({:.0}s)", t0.elapsed().as_secs_f64());

    let t1 = std::time::Instant::now();
    let concat = ctx.adapter(&AdapterSpec::new(Method::CcmConcat, comp_len, &mixture))?;
    let merge = ctx.adapter(&AdapterSpec::new(Method::CcmMerge, comp_len, &mixture))?;
    println!("[phase 2] compression adapters ready ({:.0}s)", t1.elapsed().as_secs_f64());

    // Phase 3: online evaluation over time steps.
    let ds =
        by_name(&dataset, ctx.budget.seed, &ctx.manifest().scenario, ctx.manifest().model.vocab)?;
    let ts = ctx.budget.t_values.clone();
    println!("\n[phase 3] {dataset} accuracy over online time steps (n={}):", ctx.budget.eval_n);
    println!(
        "{:>4} {:>12} {:>12} {:>12} {:>12}",
        "t", "nocontext", "full", "ccm-concat", "ccm-merge"
    );
    let base_ck = ctx.base(&mixture)?;
    for &t in &ts {
        let mut cells = Vec::new();
        for (method, ck) in [
            (Method::NoContext, &base_ck),
            (Method::Full, &base_ck),
            (Method::CcmConcat, &concat),
            (Method::CcmMerge, &merge),
        ] {
            let ev = Evaluator::new(&ctx.rt, ck);
            let p = PackPolicy::new(method, comp_len);
            let r = ev.accuracy(&p, ds.as_ref(), t, ctx.budget.eval_n)?;
            cells.push(format!("{:>11.1}%", r.accuracy * 100.0));
        }
        println!("{t:>4} {}", cells.join(" "));
    }

    // Memory story at the last step.
    let t = *ts.last().unwrap();
    let sample = ds.sample(ccm::datagen::Split::Test, 0, t);
    let lc: Vec<usize> = sample.chunks.iter().map(|c| c.len()).collect();
    let m = &ctx.manifest().model;
    println!("\npeak attention-KV at t={t}:");
    for method in [Method::Full, Method::CcmConcat, Method::CcmMerge] {
        let b = ccm::eval::memacct::peak_kv_bytes(m, method, &lc, sample.input.len(), comp_len);
        println!("  {:12} {:>8.1} KiB", method.name(), b as f64 / 1024.0);
    }
    println!("\ndone in {:.0}s total", t0.elapsed().as_secs_f64());
    Ok(())
}
