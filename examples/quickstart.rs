//! Quickstart: one online session with compressed context memory.
//!
//! Loads the AOT artifacts (test config by default so it runs in
//! seconds), feeds a short synthetic dialogue chunk-by-chunk through the
//! compression engine, and contrasts the compressed-memory footprint
//! with what raw context KV would have cost.
//!
//!   cargo run --release --example quickstart [-- --config main]

use anyhow::Result;
use ccm::compress::{target_avg_loglik, CompressItem, Engine, InferItem};
use ccm::datagen::{by_name, Split};
use ccm::eval::memacct;
use ccm::memory::MemoryStore;
use ccm::model::Checkpoint;
use ccm::runtime::Runtime;
use ccm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let config = args.str("config", "test");
    println!("== Compressed Context Memory quickstart (config {config}) ==");

    let rt = Runtime::from_config(&config)?;
    let m = &rt.manifest;
    println!(
        "model: d={} L={} V={}; scenario: T<={} chunks of <={} tokens",
        m.model.d_model, m.model.n_layers, m.model.vocab, m.scenario.t_max, m.scenario.chunk_max
    );

    // A fresh (or trained, via --checkpoint) model.
    let ckpt = args.str("checkpoint", "");
    let ck = if ckpt.is_empty() {
        Checkpoint::init(m, 7)
    } else {
        Checkpoint::load(std::path::Path::new(&ckpt), m)?
    };

    let comp_len = m.scenario.comp_len_max;
    let engine = Engine::new(&rt, &ck, comp_len)?;
    let mut mem =
        MemoryStore::concat(m.model.n_layers, m.scenario.mem_slots, m.model.d_model, comp_len);

    // An online conversation: chunks arrive one at a time.
    let ds = by_name("dialog", 42, &m.scenario, m.model.vocab)?;
    let t = m.scenario.t_max.min(4);
    let sample = ds.sample(Split::Test, 0, t);

    let mut pos = 0usize;
    let mut raw_tokens = 0usize;
    for (j, chunk) in sample.chunks.iter().enumerate() {
        let item = CompressItem { mem: &mem, chunk, pos_start: pos };
        let h = engine.compress(std::slice::from_ref(&item))?.remove(0);
        mem.update(&h)?;
        pos += chunk.len() + comp_len;
        raw_tokens += chunk.len();
        println!(
            "t={}: compressed {}-token chunk -> Mem({}) holds {} KV slots ({:.1} KiB)",
            j + 1,
            chunk.len(),
            j + 1,
            mem.len(),
            mem.kv_bytes() as f64 / 1024.0
        );
    }

    // Answer the next query from memory only (Eq. 3).
    let input = sample.input_with_target();
    let item = InferItem { mem: &mem, tokens: &input, pos_start: pos };
    let logits = &engine.infer(std::slice::from_ref(&item))?[0];
    let ll = target_avg_loglik(logits, sample.input.len(), &sample.target);

    let raw_bytes = memacct::kv_bytes(&m.model, raw_tokens);
    println!("\nquery answered with avg target log-likelihood {ll:.3}");
    println!(
        "compressed memory: {:.1} KiB vs raw context KV {:.1} KiB  ({:.1}x smaller)",
        mem.kv_bytes() as f64 / 1024.0,
        raw_bytes as f64 / 1024.0,
        raw_bytes as f64 / mem.kv_bytes().max(1) as f64
    );
    println!("(untrained weights unless --checkpoint is given — see train_e2e)");
    Ok(())
}
