//! Live-traffic replay demo: start a sharded SimCompute server, then
//! replay a mixed multi-tenant population from the paper's workload
//! generators against it through `ccm loadgen`'s library API — the
//! scenario-by-scenario operator handbook is docs/SCENARIOS.md.
//!
//!   cargo run --release --example loadgen \
//!     [-- --users 64 --rate 400 --scenario mixed --shards 2]

use std::sync::mpsc::channel;
use std::time::Duration;

use anyhow::Result;
use ccm::bench::loadgen::{drive, LoadSpec, Mix};
use ccm::compress::{Compute, SimCompute};
use ccm::coordinator::session::SessionPolicy;
use ccm::model::Manifest;
use ccm::server::{serve_sharded, BackendFactory, Client, ServerConfig};
use ccm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let users = args.usize("users", 64)?;
    let rate = args.f32("rate", 400.0)?;
    let mix = Mix::parse(&args.str("scenario", "mixed"))?;
    let shards = args.usize("shards", 2)?.max(1);

    // A small sharded server over the deterministic Sim backend with a
    // simulated per-batch compute cost (the `ccm loadgen` CLI
    // self-serves the same topology when no --addr is given).
    let m = Manifest::toy();
    let mut cfg =
        ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(m.scenario.comp_len_max));
    cfg.shards = shards;
    cfg.max_batch = 8;
    cfg.max_wait = Duration::from_millis(1);
    cfg.max_pending = 4096;
    let (ready_tx, ready_rx) = channel();
    let m2 = m.clone();
    let server = std::thread::spawn(move || {
        let factories: Vec<BackendFactory<'static>> = (0..shards)
            .map(|_| {
                let mut sim = SimCompute::from_manifest(&m2);
                sim.compress_delay = Duration::from_micros(200);
                sim.infer_delay = Duration::from_micros(200);
                let factory: BackendFactory<'static> =
                    Box::new(move || Ok(Box::new(sim) as Box<dyn Compute>));
                factory
            })
            .collect();
        serve_sharded(&m2, factories, cfg, Some(ready_tx))
    });
    let addr = ready_rx.recv()?;
    println!("server up at {addr} ({shards} shard(s)); replaying {users} users at {rate} req/s");

    // Open-loop replay: schedules are precomputed, latency is measured
    // from the scheduled send time, refusals never enter the latency
    // pool (docs/SCENARIOS.md, "refusals are not latency").
    let spec = LoadSpec {
        users,
        mix,
        rate,
        seed: 7,
        churn: 0.05,
        quality_every: 8,
        ramp_secs: 0.5,
        stream_len_max: 8,
        topk: 3,
    };
    let summary = drive(&addr, &m, &spec)?;

    for sc in &summary.scenarios {
        println!(
            "{:>8}: {:3} users, {} served / {} refused / {} lost, p50 {:.2} ms, p99 {:.2} ms",
            sc.workload.name(),
            sc.users,
            sc.bucket.ok,
            sc.bucket.refused,
            sc.bucket.lost,
            sc.bucket.p_ms(500),
            sc.bucket.p_ms(990),
        );
    }
    let q = &summary.quality;
    if q.samples > 0 {
        println!(
            "quality: {} sampled sessions, rouge {:.3}, peak-KV full/ccm ratio {:.1}x",
            q.samples, q.rouge_mean, q.kv_ratio_mean
        );
    }
    println!(
        "total: {} served / {} refused / {} lost in {:.2}s ({:.0} served/s)",
        summary.total.ok,
        summary.total.refused,
        summary.total.lost,
        summary.wall_secs,
        summary.total.ok as f64 / summary.wall_secs.max(1e-9),
    );

    let mut admin = Client::connect(&addr)?;
    admin.shutdown()?;
    server.join().expect("server thread")?;
    println!("server shut down cleanly");
    Ok(())
}
