//! Streaming demo (Figure 8/9): unbounded token stream under a hard KV
//! budget — CCM-compressed sliding window vs StreamingLLM at the same
//! budget.
//!
//!   cargo run --release --example streaming [-- --config test]

use anyhow::Result;
use ccm::eval::streaming::{stream_ppl, StreamEvalConfig};
use ccm::model::Checkpoint;
use ccm::runtime::Runtime;
use ccm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env()?;
    let config = args.str("config", "test");
    let rt = Runtime::from_config(&config)?;
    let ckpt = args.str("checkpoint", "");
    let ck = if ckpt.is_empty() {
        Checkpoint::init(&rt.manifest, 7)
    } else {
        Checkpoint::load(std::path::Path::new(&ckpt), &rt.manifest)?
    };

    let mut cfg = StreamEvalConfig::for_manifest(&rt.manifest);
    cfg.n_tokens = args.usize("stream-tokens", 512)?;
    println!(
        "== streaming under KV budget {} (sink {}, CCM memory {} slots, block {}) ==",
        cfg.max_kv, cfg.n_sink, cfg.mem_slots, cfg.compress_block
    );

    let ccm_rep = stream_ppl(&rt, &ck, &cfg, 3, true)?;
    println!(
        "CCM-concat:   ppl {:.3} ({} compressions, mean KV {:.1})",
        ccm_rep.final_ppl, ccm_rep.compressions, ccm_rep.mean_kv
    );
    let base_rep = stream_ppl(&rt, &ck, &cfg, 3, false)?;
    println!(
        "StreamingLLM: ppl {:.3} (window only, mean KV {:.1})",
        base_rep.final_ppl, base_rep.mean_kv
    );
    println!("\ncumulative ppl curve (tokens: ccm / baseline):");
    for ((tok, a), (_, b)) in ccm_rep.curve.iter().zip(base_rep.curve.iter()) {
        println!("  {tok:>6}: {a:.3} / {b:.3}");
    }
    println!(
        "(with a trained checkpoint CCM's long-range memory wins; see `ccm reproduce --exp fig8`)"
    );
    Ok(())
}
