//! Serving demo: start the coordinator server (optionally sharded into
//! N executors with `--shards`, or into N worker PROCESSES with
//! `--workers` — the example re-executes itself as each worker), drive
//! it with concurrent clients, report latency/throughput (the
//! deployment story of Table 1).
//!
//!   cargo run --release --example serve \
//!     [-- --config test --clients 4 --shards 2 --eviction lru \
//!         --reactor epoll --reactors auto --max-conns 16384 \
//!         --workers 2]

use std::sync::mpsc::channel;

use anyhow::Result;
use ccm::coordinator::session::{EvictionKind, SessionPolicy};
use ccm::datagen::{by_name, Split};
use ccm::model::Checkpoint;
use ccm::runtime::Runtime;
use ccm::server::{serve, serve_sharded, serve_workers, Client, ReactorMode, ServerConfig};
use ccm::util::cli::Args;
use ccm::util::json::Json;

/// Worker mode (`--workers N` re-execs this binary per shard): build
/// the same runtime + engine a `ccm worker` would and serve the IPC
/// protocol; configuration travels in the environment because the
/// re-exec carries no argv.
fn example_worker_main() -> Result<()> {
    let env_usize = |key: &str, default: usize| -> usize {
        std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
    };
    let config =
        std::env::var("CCM_EXAMPLE_WORKER_CONFIG").unwrap_or_else(|_| "test".to_string());
    let shard = env_usize("CCM_EXAMPLE_WORKER_SHARD", 0);
    let shards = env_usize("CCM_EXAMPLE_WORKER_SHARDS", 1);
    let manifest = ccm::model::Manifest::load(&ccm::model::artifact_dir(&config))?;
    let comp_len = match env_usize("CCM_EXAMPLE_WORKER_COMP_LEN", 0) {
        0 => manifest.scenario.comp_len_max,
        n => n,
    };
    let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(comp_len));
    cfg.shards = shards;
    cfg.max_batch = 8;
    cfg.max_wait = std::time::Duration::from_millis(2);
    cfg.max_pending = 512;
    cfg.eviction = EvictionKind::parse(
        &std::env::var("CCM_EXAMPLE_WORKER_EVICTION").unwrap_or_else(|_| "oldest".to_string()),
    )?;
    let factory =
        ccm::serve_backend_factories(&config, "", 7, comp_len, 1).pop().expect("one factory");
    ccm::server::run_worker(&manifest, factory, cfg, shard, None)
}

fn main() -> Result<()> {
    if std::env::var("CCM_EXAMPLE_WORKER").as_deref() == Ok("1") {
        return example_worker_main();
    }
    let args = Args::from_env()?;
    let config = args.str("config", "test");
    let n_clients = args.usize("clients", 4)?;
    let rounds = args.usize("rounds", 3)?;
    let shards = args.usize("shards", 1)?.max(1);
    let workers = args.usize("workers", 0)?;
    let eviction = EvictionKind::parse(&args.str("eviction", "oldest"))?;
    // --reactor beats CCM_SERVE_REACTOR beats the platform default.
    let reactor_flag = args.str_env("reactor", "CCM_SERVE_REACTOR", "auto");
    let reactor = match reactor_flag.as_str() {
        "auto" => None,
        other => Some(ReactorMode::parse(other)?),
    };
    // Epoll-mode reactor threads (SO_REUSEPORT accept sharding).
    let reactors = args
        .usize_env_auto("reactors", "CCM_SERVE_REACTORS", ccm::server::auto_reactors(), "auto")?
        .max(1);
    let max_conns = args.usize("max-conns", 0)?;

    // Server thread owns the runtime(s); with --shards N each executor
    // thread builds its own (PJRT executables are not Sync, so a
    // runtime never crosses threads).
    let (ready_tx, ready_rx) = channel();
    let cfg2 = config.clone();
    let comp_len_flag = args.usize("comp-len", 0)?;
    let server = std::thread::spawn(move || -> Result<()> {
        let manifest = ccm::model::Manifest::load(&ccm::model::artifact_dir(&cfg2))?;
        let comp_len =
            if comp_len_flag == 0 { manifest.scenario.comp_len_max } else { comp_len_flag };
        let mut cfg = ServerConfig::new("127.0.0.1:0", SessionPolicy::concat(comp_len));
        cfg.max_batch = 8;
        cfg.max_wait = std::time::Duration::from_millis(2);
        cfg.max_pending = 512;
        cfg.shards = shards;
        cfg.eviction = eviction;
        if let Some(mode) = reactor {
            cfg.reactor = mode;
        }
        cfg.reactors = reactors;
        if max_conns > 0 {
            cfg.max_conns = max_conns;
        }
        if workers > 0 {
            // Cross-process topology: each shard executor is a child
            // process of this example (re-exec'd in worker mode).
            let exe = std::env::current_exe()?;
            let config = cfg2.clone();
            let mode = ccm::server::WorkerMode::Spawn {
                count: workers,
                launcher: Box::new(move |shard| {
                    let mut cmd = std::process::Command::new(&exe);
                    cmd.env("CCM_EXAMPLE_WORKER", "1")
                        .env("CCM_EXAMPLE_WORKER_CONFIG", &config)
                        .env("CCM_EXAMPLE_WORKER_SHARD", shard.to_string())
                        .env("CCM_EXAMPLE_WORKER_SHARDS", workers.to_string())
                        .env("CCM_EXAMPLE_WORKER_COMP_LEN", comp_len_flag.to_string())
                        .env("CCM_EXAMPLE_WORKER_EVICTION", eviction.name());
                    cmd
                }),
            };
            return serve_workers(cfg, mode, Some(ready_tx));
        }
        if shards == 1 {
            let rt = Runtime::load(manifest)?;
            let ck = Checkpoint::init(&rt.manifest, 7);
            rt.warmup(&ccm::SERVE_WARMUP).ok();
            return serve(&rt, &ck, cfg, Some(ready_tx));
        }
        // Same per-shard runtime/engine wiring as `ccm serve --shards N`.
        let factories = ccm::serve_backend_factories(&cfg2, "", 7, comp_len, shards);
        serve_sharded(&manifest, factories, cfg, Some(ready_tx))
    });
    let addr = ready_rx.recv()?;
    if workers > 0 {
        // `ready` fires when the FRONT-END port is bound; the worker
        // processes are still starting and requests racing them get
        // `shard_unavailable` by design. Gate the demo load on every
        // per_worker stats row reporting up.
        let mut admin = Client::connect(&addr)?;
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(120);
        loop {
            let stats = admin.stats()?;
            let up = stats
                .opt("per_worker")
                .and_then(|v| v.arr().ok())
                .map(|rows| {
                    rows.len() == workers
                        && rows.iter().all(|r| r.opt("up") == Some(&Json::Bool(true)))
                })
                .unwrap_or(false);
            if up {
                break;
            }
            anyhow::ensure!(
                std::time::Instant::now() < deadline,
                "worker processes did not come up"
            );
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
    }
    println!(
        "server up at {addr} ({}, eviction {}, reactor {} x{reactors}); \
         {n_clients} clients x {rounds}",
        if workers > 0 {
            format!("{workers} worker process(es)")
        } else {
            format!("{shards} shard(s)")
        },
        eviction.name(),
        reactor.map_or("auto", ReactorMode::name)
    );

    // Concurrent clients, one session each, multiple interaction rounds.
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let addr = addr.clone();
        let config = config.clone();
        handles.push(std::thread::spawn(move || -> Result<(usize, f64)> {
            let rt_manifest = ccm::model::Manifest::load(&ccm::model::artifact_dir(&config))?;
            let ds = by_name("lamp", 11, &rt_manifest.scenario, rt_manifest.model.vocab)?;
            let mut client = Client::connect(&addr)?;
            let mut queries = 0usize;
            let mut lat_ms = 0.0f64;
            for round in 1..=rounds {
                let s = ds.sample(Split::Test, c, round);
                client.add_context(&format!("client{c}"), s.chunks.last().unwrap())?;
                let tq = std::time::Instant::now();
                let next = client.query(&format!("client{c}"), &s.input, 3)?;
                lat_ms += tq.elapsed().as_secs_f64() * 1e3;
                queries += 1;
                assert_eq!(next.len(), 3);
                assert!(next[0].1 <= 0.0, "logprob must be <= 0");
            }
            Ok((queries, lat_ms))
        }));
    }
    let mut total_q = 0usize;
    let mut total_lat = 0.0;
    for h in handles {
        let (q, l) = h.join().expect("client thread")?;
        total_q += q;
        total_lat += l;
    }
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "served {total_q} queries (+{} compressions) in {secs:.2}s: \
         {:.1} q/s, mean latency {:.1} ms",
        total_q,
        total_q as f64 / secs,
        total_lat / total_q as f64
    );

    // Stats + shutdown.
    let mut admin = Client::connect(&addr)?;
    let stats = admin.stats()?;
    println!(
        "server sessions: {} (kv {} B, pending {}, overload rejections {}, evicted {})",
        stats.get("sessions")?.usize()?,
        stats.get("kv_bytes")?.usize()?,
        stats.get("pending")?.usize()?,
        stats.get("rejected_overload")?.usize()?,
        stats.get("sessions_evicted")?.usize()?
    );
    admin.shutdown()?;
    server.join().expect("server thread")?;
    println!("server shut down cleanly");
    Ok(())
}
